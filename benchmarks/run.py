"""Benchmark harness — one entry per paper table/figure plus the roofline
deliverable.  Prints ``name,us_per_call,derived`` CSV rows.

  table1        — iteration/communication counts at a matched AUC target for
                  PPD-SG (K=1), NP-PPD-SG (I=1) and CoDA       [paper Table 1]
  vary_k        — iterations to target AUC for K ∈ {1,2,4,8}   [Fig. 1-3 (a)]
  vary_i        — AUC + comm rounds for I ∈ {1,8,32,64}, K=4   [Fig. 1-3 (b)]
  tradeoff      — largest harmless I for K=2 vs K=8            [Fig. 4-5]
  growing_i     — fixed I vs I_s = I0·3^{s-1}                  [Appendix H]
  kernels       — Pallas kernels (interpret) vs jnp oracles microbench
  window_step   — CoDA window step wall time vs I (CPU)
  sharded_window— vmap oracle vs shard_map executor: wall-clock + HLO
                  all-reduce bytes for I ∈ {1,4,16,64}; run with
                  --force-host-devices 8 on a CPU host
  overlap_window— overlapped vs blocking window averaging at equal comm
                  bytes: fused ppermute-ring window pairs vs two blocking
                  steps, I ∈ {1,4,16} × {coda, codasca}, plus the
                  no-all-reduce/interleaving HLO asserts; needs
                  --force-host-devices 8 on a CPU host
  hetero_window — heterogeneous shards: CoDA vs CODASCA final AUC at EQUAL
                  comm rounds for Dirichlet α ∈ {0.1, 1, ∞} × I ∈ {4,16,64},
                  plus the per-round payload each algorithm ships
  fault_tolerance — robustness tier: clean vs fault-injected training
                  (20% per-window dropout + 1-window stragglers with
                  bounded staleness, seed-deterministic FaultPlan) at
                  EQUAL comm rounds; asserts |ΔAUC| ≤ 0.02, bit-for-bit
                  schedule replay, and the masked window's ONE-all-reduce
                  payload contract (HLO legs need --force-host-devices 8)
  objective_sweep — pluggable objectives: full-AUC vs pAUC-DRO training at
                  EQUAL comm rounds on imbalanced Dirichlet(0.1) shards
                  with planted hard negatives; pAUC-DRO must win on
                  partial-AUC@FPR≤0.3 (asserted, deterministic seeds)
  moe_dispatch  — sorted dropless MoE dispatch vs padded capacity C=T on
                  the eval hot path: wall-clock + dispatch/peak buffer
                  bytes at bitwise-equal routing across dbrx/arctic
                  shapes, plus the analytic buffer ratio for the REAL
                  configs (the E/(2·top_k) acceptance bound)
  serve_load    — continuous-batching serving tier: synthetic-trace load
                  (batch / poisson / bursty arrivals) through the serving
                  engine, reporting p50/p99 TTFT, p50/p99 completion
                  latency and tokens/s; asserts batched chunked prefill
                  beats the token-per-tick engine at bitwise-identical
                  generated tokens per request
  roofline      — per (arch × shape × mesh) three-term roofline from the
                  dry-run artifacts (run repro.launch.dryrun first)

Flags: --fast trims the sweep lists; --smoke is the CI tier (tiny T/I/batch,
fixed seed, < 2 min on a CPU host — the bench-smoke job and local sanity
checks share this one entry point); --json PATH dumps every emitted row
plus the structured comm-accounting records (the CI artifact).

Run:  PYTHONPATH=src python -m benchmarks.run [--only vary_k] [--fast]
      PYTHONPATH=src python -m benchmarks.run --only sharded_window \
          --force-host-devices 8 --smoke --json comm.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import audit as A
from repro.analysis import hlo as H
from repro.configs.base import mlp_config
from repro.core import coda, schedules
from repro.data import DataConfig, ShardedDataset
from repro.metrics import streaming as SM
from repro.models import model as M

MCFG = mlp_config(n_features=32, d=64)
ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
ROWS = []
COMM = {}  # structured comm-accounting records (--json artifact)


def emit(name: str, us_per_call: float, derived):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def emit_comm(name: str, record: dict):
    COMM[name] = record


# --------------------------------------------------------------------------
# shared convergence runner
# --------------------------------------------------------------------------
def _run(K, I, *, stages=3, T0=64, batch=32, seed=0, eta0=0.5, grow_I=False,
         target=0.88, eval_every_windows=2, algorithm="coda",
         dirichlet_alpha=None, n_data=8192, obj="auc", pauc_beta=0.3,
         hard_neg_frac=0.0, optimizer="sgd", opt_dtype=jnp.float32,
         opt_beta=0.9, opt_eps=1e-6, shampoo_block=16, precond_every=1):
    key = jax.random.PRNGKey(seed)
    dcfg = DataConfig(kind="features", n_features=32, signal=1.5,
                      hard_neg_frac=hard_neg_frac)
    ds = ShardedDataset(key, dcfg, n_data, K, target_p=0.71,
                        dirichlet_alpha=dirichlet_alpha)
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=ds.p_pos, algorithm=algorithm,
                           objective=obj, pauc_beta=pauc_beta,
                           optimizer=optimizer, opt_dtype=opt_dtype,
                           opt_beta=opt_beta, opt_eps=opt_eps,
                           shampoo_block=shampoo_block,
                           precond_every=precond_every)
    test = ds.full(1024)

    def scores(state):
        p0 = jax.tree_util.tree_map(lambda x: x[0], state["params"])
        h, _ = M.score(MCFG, p0, {"features": test["features"]})
        return h

    auc_m = SM.make_metric("auc", "exact")
    pauc_m = SM.make_metric("pauc", "exact", beta=pauc_beta)

    def auc(state):
        return auc_m.compute(scores(state), test["labels"])

    def pauc(state):
        return pauc_m.compute(scores(state), test["labels"])

    sched = schedules.ScheduleConfig(n_workers=K, eta0=eta0, T0=T0, I0=I,
                                     grow_I=grow_I)
    exe = coda.make_executor(MCFG, ccfg, "vmap", donate=False)
    state = exe.place(coda.init_state(key, MCFG, ccfg))

    iters = rounds = 0
    iters_to_target = None
    t0 = time.time()
    for st in schedules.stages(sched, stages):
        for w in range(-(-st.T // st.I)):
            key, sk = jax.random.split(key)
            state, _ = exe.window_step(state, ds.sample_window(sk, st.I, batch),
                                       jnp.float32(st.eta))
            iters += st.I
            rounds += 1
            if iters_to_target is None and w % eval_every_windows == 0:
                if auc(state) >= target:
                    iters_to_target = iters
        key, sk = jax.random.split(key)
        state = exe.stage_end(state, ds.sample_alpha_batch(sk, st.m))
        rounds += 1
    wall = time.time() - t0
    stage_list = schedules.stages(sched, stages)
    return dict(auc=auc(state), pauc=pauc(state), iters=iters, rounds=rounds,
                wall=wall,
                iters_to_target=iters_to_target or iters,
                us_per_iter=wall / iters * 1e6,
                payload_bytes=coda.window_payload_bytes(state),
                opt_state_bytes=coda.opt_state_bytes(state),
                comm_bytes=coda.comm_bytes(
                    stage_list, state,
                    stage_bytes=coda.stage_payload_bytes(ccfg)))


# --------------------------------------------------------------------------
# paper experiments
# --------------------------------------------------------------------------
def bench_vary_k(fast=False, smoke=False):
    """Fig 1-3(a): fixing I, larger K needs fewer iterations (linear speedup)."""
    for K in ([1, 4] if fast else [1, 2, 4, 8]):
        r = _run(K, 8, stages=2 if fast else 3)
        emit(f"vary_k/K={K}/iters_to_0.88auc", r["us_per_iter"],
             r["iters_to_target"])
        emit(f"vary_k/K={K}/final_auc", r["us_per_iter"], round(r["auc"], 4))


def bench_vary_i(fast=False, smoke=False):
    """Fig 1-3(b): fixing K, skipping communication up to a threshold I does
    not hurt AUC but slashes communication rounds."""
    for I in ([1, 32] if fast else [1, 8, 32, 64]):
        r = _run(4, I, stages=2 if fast else 3)
        emit(f"vary_i/I={I}/final_auc", r["us_per_iter"], round(r["auc"], 4))
        emit(f"vary_i/I={I}/comm_rounds", r["us_per_iter"], r["rounds"])


def bench_tradeoff(fast=False, smoke=False):
    """Fig 4-5: smaller K tolerates a larger I before AUC degrades."""
    for K in [2, 8]:
        base = _run(K, 1, stages=2)["auc"]
        max_ok = 1
        for I in ([16, 64] if fast else [8, 16, 64, 128]):
            r = _run(K, I, stages=2)
            if r["auc"] >= base - 0.02:
                max_ok = I
        emit(f"tradeoff/K={K}/max_harmless_I", 0.0, max_ok)


def bench_growing_i(fast=False, smoke=False):
    """Appendix H: growing I_s = I0·3^(s-1) matches fixed-I accuracy with
    fewer rounds (later stages have smaller η ⇒ less drift)."""
    fixed = _run(4, 8, stages=2 if fast else 3)
    grow = _run(4, 8, stages=2 if fast else 3, grow_I=True)
    emit("growing_i/fixed_I8_auc", fixed["us_per_iter"], round(fixed["auc"], 4))
    emit("growing_i/grow_I8_auc", grow["us_per_iter"], round(grow["auc"], 4))
    emit("growing_i/fixed_rounds", 0.0, fixed["rounds"])
    emit("growing_i/grow_rounds", 0.0, grow["rounds"])


def bench_table1(fast=False, smoke=False):
    """Table 1: measured iteration + communication counts to the SAME AUC
    target for the three algorithms."""
    tgt = 0.88
    runs = [("PPD-SG(K=1)", _run(1, 1, stages=2 if fast else 3, target=tgt), 1),
            ("NP-PPD-SG(K=8,I=1)", _run(8, 1, stages=2 if fast else 3,
                                        target=tgt), 1),
            ("CoDA(K=8,I=16)", _run(8, 16, stages=2 if fast else 3,
                                    target=tgt), 16)]
    for name, r, I in runs:
        emit(f"table1/{name}/iters_to_target", r["us_per_iter"],
             r["iters_to_target"])
        emit(f"table1/{name}/comm_rounds_to_target", 0.0,
             -(-r["iters_to_target"] // I))


# --------------------------------------------------------------------------
# kernels
# --------------------------------------------------------------------------
def _time(fn, *args, n=20):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / n * 1e6


def bench_kernels(fast=False, smoke=False):
    from repro.kernels import ref
    from repro.kernels.auc_loss import auc_loss
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.prox_update import prox_update
    key = jax.random.PRNGKey(0)
    B, S, Hh, KV, hd = 1, 512, 4, 2, 64
    q = jax.random.normal(key, (B, S, Hh, hd))
    k = jax.random.normal(key, (B, S, KV, hd))
    v = jax.random.normal(key, (B, S, KV, hd))
    f_ref = jax.jit(lambda q, k, v: ref.attention_full(q, k, v, causal=True))
    emit("kernels/attention_ref_jnp", _time(f_ref, q, k, v), f"S={S}")
    f_pal = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                                    block_q=128, block_k=128,
                                                    interpret=True))
    emit("kernels/attention_pallas_interpret", _time(f_pal, q, k, v, n=3),
         "interpret=correctness-mode; CPU us not meaningful for TPU")

    h = jax.random.uniform(key, (8192,))
    y = (jax.random.uniform(key, (8192,)) < 0.7).astype(jnp.float32)
    g_ref = jax.jit(lambda h, y: ref.auc_loss_ref(h, y, 0.1, 0.2, 0.0, 0.71))
    emit("kernels/auc_loss_ref_jnp", _time(g_ref, h, y), "T=8192")
    g_pal = jax.jit(lambda h, y: auc_loss(h, y, 0.1, 0.2, 0.0, 0.71,
                                          interpret=True))
    emit("kernels/auc_loss_pallas_interpret", _time(g_pal, h, y, n=3), "T=8192")

    vv = jax.random.normal(key, (1 << 20,))
    p_ref = jax.jit(lambda v: ref.prox_update_ref(v, v, v, 0.1, 0.5))
    emit("kernels/prox_ref_jnp", _time(p_ref, vv), "N=1M")
    p_pal = jax.jit(lambda v: prox_update(v, v, v, 0.1, 0.5, interpret=True))
    emit("kernels/prox_pallas_interpret", _time(p_pal, vv, n=3), "N=1M")


def bench_sharded_window(fast=False, smoke=False):
    """The tentpole's measurement: communication is real under shard_map, so
    comm-bytes come from the compiled HLO and wall-clock includes the actual
    all-reduce — while the per-window wire bytes stay constant as I grows
    (the paper's Theorem-1 point, now compiler-verified)."""
    n = jax.device_count()
    if n < 2:
        emit("sharded_window/skipped", 0.0,
             "needs >1 device; rerun with --force-host-devices 8")
        return
    from repro.launch import mesh as MESH
    mesh = MESH.make_worker_mesh()
    K = n
    key = jax.random.PRNGKey(0)
    dcfg = DataConfig(kind="features", n_features=32)
    from repro.data.synthetic import sample_online
    compresses = ("",) if smoke else ("", "int8")
    for compress in compresses:
        for algorithm in ("coda", "codasca"):
            ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7,
                                   avg_compress=compress, algorithm=algorithm)
            execs = {
                "vmap": coda.make_executor(MCFG, ccfg, "vmap", donate=False),
                "shard_map": coda.make_executor(MCFG, ccfg, "shard_map",
                                                mesh=mesh, donate=False),
            }
            Is = [1, 4] if smoke else ([1, 16] if fast else [1, 4, 16, 64])
            for I in Is:
                wb = sample_online(key, dcfg, (I, K, 16 if smoke else 32))
                state0 = coda.init_state(key, MCFG, ccfg)
                tag = f"sharded_window/{algorithm}/{compress or 'fp32'}/I={I}"
                for name, exe in execs.items():
                    st = exe.place(state0)
                    step = lambda s: exe.window_step(s, wb, 0.1)
                    us = _time(step, st, n=2 if smoke else 5)
                    emit(f"{tag}/{name}_us", us, f"us_per_iter={us / I:.0f}")
                txt = execs["shard_map"].window_fn(state0, wb).lower(
                    state0, wb, jnp.float32(0.1)).compile().as_text()
                coll = H.collective_bytes(txt)
                payload = coda.window_payload_bytes(state0, compress or None)
                emit(f"{tag}/hlo_comm", 0.0,
                     f"all_reduce_ops={coll['all-reduce']['count']};"
                     f"all_reduce_bytes={coll['all-reduce']['bytes']};"
                     f"all_gather_ops={coll['all-gather']['count']};"
                     f"all_gather_bytes={coll['all-gather']['bytes']};"
                     f"payload_bytes={payload}")
                emit_comm(tag, {
                    "algorithm": algorithm, "compress": compress or "fp32",
                    "I": I, "K": K,
                    "payload_bytes": payload,
                    "model_bytes": coda.model_bytes(state0, compress or None),
                    "hlo": {k: {"count": coll[k]["count"],
                                "bytes": coll[k]["bytes"]}
                            for k in ("all-reduce", "all-gather")},
                })
                if not compress:
                    # the acceptance invariant, enforced at bench time too:
                    # ONE all-reduce, operand bytes == documented payload
                    A.assert_window_payload(txt, payload)


def bench_overlap_window(fast=False, smoke=False):
    """The overlap tentpole's measurement: at EQUAL comm bytes, the fused
    overlapped window pair (chunked ppermute rings hidden under next-window
    compute) vs two blocking window steps — per-2-window wall clock for
    I ∈ {1, 4, 16} × both algorithms, plus the HLO acceptance invariants:
    the overlapped module is C permute chains per ring interleaved with
    dot compute (NO all-reduce), and its final state matches the blocking
    path to fp32 tolerance.

    Wall-clock caveat: on forced-host CPU "devices" every collective is an
    in-process rendezvous (~0.3 ms each, measured) and there is no wire
    time to hide, so the ring's 2·(R−1) serialized hops lose to the single
    shared-memory all-reduce by construction — the speedup row is honest
    about that.  The schedule the HLO asserts (C independent permute
    chains, no barrier against next-window compute) is the thing that wins
    on a real TPU mesh, where hops are async DMAs; on-hardware validation
    rides the same ROADMAP item as the int8 wire check."""
    n = jax.device_count()
    if n < 2:
        emit("overlap_window/skipped", 0.0,
             "needs >1 device; rerun with --force-host-devices 8")
        return
    from repro.core import bucketing
    from repro.data.synthetic import sample_online
    from repro.launch import mesh as MESH
    mesh = MESH.make_worker_mesh()
    K, CHUNKS = n, 4
    key = jax.random.PRNGKey(0)
    dcfg = DataConfig(kind="features", n_features=32)
    Is = (1, 4) if smoke else ((1, 16) if fast else (1, 4, 16))
    reps = 3 if smoke else 9
    for algorithm in ("coda", "codasca"):
        base = coda.CoDAConfig(n_workers=K, p_pos=0.7, algorithm=algorithm)
        over = coda.CoDAConfig(n_workers=K, p_pos=0.7, algorithm=algorithm,
                               overlap_chunks=CHUNKS)
        exe_off = coda.make_executor(MCFG, base, "shard_map", mesh=mesh,
                                     donate=False)
        exe_on = coda.make_executor(MCFG, over, "shard_map", mesh=mesh,
                                    donate=False)
        for I in Is:
            wb2 = sample_online(key, dcfg, (2, I, K, 16 if smoke else 32))
            wb_a = jax.tree_util.tree_map(lambda l: l[0], wb2)
            wb_b = jax.tree_util.tree_map(lambda l: l[1], wb2)
            state0 = coda.init_state(key, MCFG, base)
            tag = f"overlap_window/{algorithm}/I={I}"

            # equal work: one fused pair call vs two blocking window calls
            def pair_on(s):
                return exe_on.window_pair_step(s, wb2, 0.1)

            def pair_off(s):
                s1, l1 = exe_off.window_step(s, wb_a, 0.1)
                s2, l2 = exe_off.window_step(s1, wb_b, 0.1)
                return s2, l2

            st = exe_on.place(state0)
            med = {}
            for name, fn in (("on", pair_on), ("off", pair_off)):
                jax.block_until_ready(fn(st))  # compile
                ts = []
                for _ in range(reps):
                    t0 = time.time()
                    jax.block_until_ready(fn(st))
                    ts.append((time.time() - t0) * 1e6)
                med[name] = float(np.median(ts))
                emit(f"{tag}/overlap_{name}_us", med[name],
                     f"us_per_iter={med[name] / (2 * I):.0f}")
            emit(f"{tag}/overlap_speedup", 0.0,
                 round(med["off"] / med["on"], 3))

            # equivalence at fp32 tolerance + identical logical comm bytes
            s_on, _ = pair_on(st)
            s_off, _ = pair_off(st)
            err = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
                lambda a, b: float(jnp.max(jnp.abs(a - b))), s_on, s_off)))
            assert err < 1e-5, (tag, err)
            payload = coda.window_payload_bytes(state0)

            # HLO acceptance: C permute chains per ring, no all-reduce,
            # interleaved with the second window's dots
            mats, _, _ = bucketing._state_mats(state0)
            if algorithm == "codasca":
                mats = mats * 2          # variates ride the same buckets
            ring = bucketing.RingSpec("data", K, CHUNKS)
            sizes = bucketing.bucket_sizes(mats)
            n_hops = 2 * bucketing.ring_hop_count(sizes, ring)  # 2 rings
            n_chains = 2 * bucketing.ring_chain_count(sizes, ring)
            txt = exe_on.window_pair_fn(state0, wb2).lower(
                state0, wb2, jnp.float32(0.1)).compile().as_text()
            # chain independence is only analyzable when the local steps
            # lower as a while loop (I >= 2, see permute_chain_components)
            A.assert_overlapped_window(txt, n_hops=n_hops,
                                       n_chains=n_chains if I > 1 else None)
            emit(f"{tag}/hlo", 0.0,
                 f"collective_permutes={n_hops};"
                 f"independent_chains={n_chains};all_reduces=0;"
                 f"chunks={CHUNKS}")
            emit_comm(tag, {
                "algorithm": algorithm, "I": I, "K": K, "chunks": CHUNKS,
                "payload_bytes": payload,
                "comm_bytes_per_pair": 2 * payload,   # identical on/off
                "overlapped_bytes_per_pair": payload,
                "exposed_bytes_per_pair": payload,
                "median_us": med, "max_state_err": err,
                "hlo_permute_hops": n_hops,
            })


def bench_hetero_window(fast=False, smoke=False):
    """Heterogeneous shards (the regime the paper's analysis excludes):
    Dirichlet(α) label-skewed partitions, CoDA vs CODASCA at the SAME
    schedule — equal comm rounds, CODASCA shipping 2x the payload per round
    and buying back the drift the skew induces.  α = ∞ is the IID control
    where both algorithms must agree."""
    inf = float("inf")
    alphas = (0.1, inf) if (fast or smoke) else (0.1, 1.0, inf)
    Is = (4, 16) if (fast or smoke) else (4, 16, 64)
    kw = dict(stages=2, T0=24, batch=16, n_data=2048) if smoke else {}
    for alpha in alphas:
        for I in Is:
            res = {}
            for algorithm in ("coda", "codasca"):
                r = _run(8, I, algorithm=algorithm,
                         dirichlet_alpha=None if np.isinf(alpha) else alpha,
                         **kw)
                res[algorithm] = r
                tag = f"hetero_window/alpha={alpha:g}/I={I}/{algorithm}"
                emit(f"{tag}/final_auc", r["us_per_iter"], round(r["auc"], 4))
                emit(f"{tag}/comm", 0.0,
                     f"rounds={r['rounds']};payload={r['payload_bytes']};"
                     f"total_bytes={r['comm_bytes']}")
            emit(f"hetero_window/alpha={alpha:g}/I={I}/codasca_auc_gain", 0.0,
                 round(res["codasca"]["auc"] - res["coda"]["auc"], 4))
            emit_comm(f"hetero_window/alpha={alpha:g}/I={I}", {
                "alpha": None if np.isinf(alpha) else alpha, "I": I,
                **{a: {"auc": res[a]["auc"], "rounds": res[a]["rounds"],
                       "payload_bytes": res[a]["payload_bytes"],
                       "comm_bytes": res[a]["comm_bytes"]}
                   for a in ("coda", "codasca")},
            })


def bench_optimizer_window(fast=False, smoke=False):
    """The optimizer-seam tentpole's measurement: preconditioned LOCAL
    primal steps vs plain prox-SGD at the SAME schedule — equal comm
    rounds, identical window payload (the optimizer state never crosses
    the wire; the audit legs pin that byte-exactly) — on α=0.1
    Dirichlet-skewed shards, where per-coordinate/per-block adaptivity is
    worth the local memory.  Asserted here:

      * sm3 and shampoo_blocked each beat sgd's final AUC at equal comm
        rounds (the acceptance criterion — adaptivity must buy accuracy,
        not just burn local FLOPs);
      * bf16 optimizer state (stochastic-rounded stores, fp32 master math
        in-kernel) is ≥ 1.9× smaller than fp32 AND lands within 0.005
        AUC of the fp32 run — memory halved at parity;
      * the window payload is identical across all optimizers (equal
        bytes per round is what makes the comparison fair)."""
    K, I = 8, 8
    kw = dict(stages=2, T0=24, batch=16, n_data=2048) if smoke else \
        (dict(stages=2) if fast else {})
    # per-optimizer η: preconditioned directions are unit-scaled per
    # coordinate (sm3) or grafted to the gradient norm (shampoo), so they
    # tolerate — and want — their own step size
    etas = {"sgd": 0.5, "sm3": 0.3, "shampoo_blocked": 0.5}
    res = {}
    for optname in ("sgd", "sm3", "shampoo_blocked"):
        res[optname] = {}
        dts = (("fp32", jnp.float32),) if optname == "sgd" else \
            (("fp32", jnp.float32), ("bf16", jnp.bfloat16))
        for dtname, dt in dts:
            r = _run(K, I, dirichlet_alpha=0.1, eta0=etas[optname],
                     optimizer=optname, opt_dtype=dt, shampoo_block=16,
                     precond_every=2, **kw)
            res[optname][dtname] = r
            tag = f"optimizer_window/{optname}/{dtname}"
            emit(f"{tag}/final_auc", r["us_per_iter"], round(r["auc"], 4))
            emit(f"{tag}/opt_state_bytes", 0.0, r["opt_state_bytes"])
            emit(f"{tag}/step_us", r["us_per_iter"],
                 round(r["us_per_iter"], 1))
            emit(f"{tag}/comm", 0.0,
                 f"rounds={r['rounds']};payload={r['payload_bytes']}")

    sgd = res["sgd"]["fp32"]
    for optname in ("sm3", "shampoo_blocked"):
        r32, r16 = res[optname]["fp32"], res[optname]["bf16"]
        # equal comm rounds + identical window payload: the comparison is
        # at equal communication, the seam's whole point
        assert r32["rounds"] == sgd["rounds"], (optname, r32["rounds"])
        assert r32["payload_bytes"] == sgd["payload_bytes"], optname
        gain = r32["auc"] - sgd["auc"]
        emit(f"optimizer_window/{optname}/auc_gain_vs_sgd", 0.0,
             round(gain, 4))
        assert gain > 0, \
            f"{optname} must beat sgd at equal comm rounds: " \
            f"{r32['auc']:.4f} vs {sgd['auc']:.4f}"
        ratio = r32["opt_state_bytes"] / max(1, r16["opt_state_bytes"])
        gap = abs(r16["auc"] - r32["auc"])
        emit(f"optimizer_window/{optname}/bf16_state_reduction", 0.0,
             round(ratio, 2))
        emit(f"optimizer_window/{optname}/bf16_auc_gap", 0.0, round(gap, 4))
        assert ratio >= 1.9, f"{optname}: bf16 state reduction {ratio:.2f}x"
        assert gap <= 0.005, \
            f"{optname}: bf16 AUC gap {gap:.4f} vs fp32 (want <= 0.005)"
    emit_comm("optimizer_window", {
        "K": K, "I": I, "alpha": 0.1,
        **{o: {dt: {"auc": r["auc"], "rounds": r["rounds"],
                    "payload_bytes": r["payload_bytes"],
                    "opt_state_bytes": r["opt_state_bytes"],
                    "us_per_iter": r["us_per_iter"]}
               for dt, r in res[o].items()}
           for o in res},
    })


def bench_fault_tolerance(fast=False, smoke=False):
    """The robustness tentpole's measurement: clean vs fault-injected
    training at the SAME schedule — equal comm rounds — for CoDA and
    CODASCA.  The injected run draws a seed-deterministic schedule of 20%
    per-window dropout plus 1-window stragglers (merged with bounded
    staleness, ``max_staleness=1``) from ``core/faults.FaultPlan``; the
    masked participant-mean averaging must buy the fault tolerance without
    giving up convergence.  Asserted here:

      * |AUC_faulty − AUC_clean| <= 0.02 at equal comm rounds (the
        acceptance criterion);
      * the fault-injected run replays bit-for-bit from (PRNG seed,
        fault seed) — two runs end in byte-identical states;
      * the compiled masked window is still exactly ONE all-reduce per
        dtype bucket, operand bytes == documented payload + the weight
        lane(s), via the same audit R1 checker CI runs (needs >1 device;
        emits a skip row otherwise)."""
    from repro.core import schedules as SCH
    K = 8
    batch = 16 if smoke else 32
    n_data = 2048 if smoke else 8192
    stages = 2 if (fast or smoke) else 3
    T0 = 24 if smoke else 64
    I = 8
    key = jax.random.PRNGKey(0)
    dcfg = DataConfig(kind="features", n_features=32, signal=1.5)
    ds = ShardedDataset(key, dcfg, n_data, K, target_p=0.71)
    test = ds.full(1024)
    auc_m = SM.make_metric("auc", "exact")

    def final_auc(state):
        p0 = jax.tree_util.tree_map(lambda x: x[0], state["params"])
        h, _ = M.score(MCFG, p0, {"features": test["features"]})
        return float(auc_m.compute(h, test["labels"]))

    sched = SCH.ScheduleConfig(n_workers=K, eta0=0.5, T0=T0, I0=I)
    fault_kw = dict(participation=0.8, straggler_prob=0.1,
                    straggler_windows=1, max_staleness=1, fault_seed=7)
    for algorithm in ("coda", "codasca"):
        cfgs = {"clean": coda.CoDAConfig(n_workers=K, p_pos=ds.p_pos,
                                         algorithm=algorithm),
                "faulty": coda.CoDAConfig(n_workers=K, p_pos=ds.p_pos,
                                          algorithm=algorithm, **fault_kw)}
        res = {}
        for name in ("clean", "faulty", "replay"):
            ccfg = cfgs["faulty" if name == "replay" else name]
            t0 = time.time()
            r = coda.fit(key, MCFG, ccfg, sched, stages,
                         lambda k, n: ds.sample_window(k, n, batch),
                         ds.sample_alpha_batch)
            wall = time.time() - t0
            res[name] = r
            if name != "replay":
                tag = f"fault_tolerance/{algorithm}/{name}"
                emit(f"{tag}/final_auc", wall / max(r.iterations, 1) * 1e6,
                     round(final_auc(r.state), 4))
                emit(f"{tag}/comm", 0.0,
                     f"rounds={r.comm_rounds};"
                     f"payload={coda.window_payload_bytes(r.state, masked=name == 'faulty')}")

        # equal comm rounds: the fault schedule drops *contributions*, not
        # collectives — every window still runs its one masked all-reduce
        assert res["clean"].comm_rounds == res["faulty"].comm_rounds, \
            (algorithm, res["clean"].comm_rounds, res["faulty"].comm_rounds)
        gap = abs(final_auc(res["faulty"].state)
                  - final_auc(res["clean"].state))
        assert gap <= 0.02, (algorithm, gap)
        emit(f"fault_tolerance/{algorithm}/auc_gap", 0.0, round(gap, 4))

        # seed determinism: the faulty run replays byte-for-byte
        replay_err = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            res["faulty"].state, res["replay"].state)))
        assert replay_err == 0.0, (algorithm, replay_err)
        emit(f"fault_tolerance/{algorithm}/replay_max_err", 0.0, replay_err)
        emit_comm(f"fault_tolerance/{algorithm}", {
            "algorithm": algorithm, "K": K, "fault_knobs": fault_kw,
            "auc": {n: final_auc(res[n].state) for n in ("clean", "faulty")},
            "auc_gap": gap, "replay_max_err": replay_err,
            "comm_rounds": {n: res[n].comm_rounds
                            for n in ("clean", "faulty")},
            "payload_bytes": {
                "clean": coda.window_payload_bytes(res["clean"].state),
                "faulty": coda.window_payload_bytes(res["faulty"].state,
                                                    masked=True)},
        })

    # masked window HLO contract: ONE all-reduce per dtype bucket, operand
    # bytes == documented payload + weight lane(s) (the audit R1 checker)
    if jax.device_count() < 2:
        emit("fault_tolerance/hlo/skipped", 0.0,
             "needs >1 device; rerun with --force-host-devices 8")
        return
    from repro.data.synthetic import sample_online
    from repro.launch import mesh as MESH
    mesh = MESH.make_worker_mesh()
    Kd = jax.device_count()
    for algorithm in ("coda", "codasca"):
        ccfg = coda.CoDAConfig(n_workers=Kd, p_pos=0.7,
                               algorithm=algorithm, **fault_kw)
        exe = coda.make_executor(MCFG, ccfg, "shard_map", mesh=mesh,
                                 donate=False)
        wb = sample_online(key, dcfg, (4, Kd, 16))
        state0 = coda.init_state(key, MCFG, ccfg)
        fl = {"weights": jnp.ones((Kd,), jnp.float32),
              "resync": jnp.ones((Kd,), jnp.float32)}
        txt = exe.window_fn(state0, wb).lower(
            state0, wb, jnp.float32(0.1), fl).compile().as_text()
        payload = coda.window_payload_bytes(state0, masked=True)
        A.assert_window_payload(
            txt, payload,
            by_dtype=coda.window_payload_by_dtype(state0, masked=True))
        coll = H.collective_bytes(txt)
        emit(f"fault_tolerance/hlo/{algorithm}", 0.0,
             f"all_reduce_ops={coll['all-reduce']['count']};"
             f"all_reduce_bytes={coll['all-reduce']['bytes']};"
             f"masked_payload_bytes={payload}")


def bench_objective_sweep(fast=False, smoke=False):
    """The objective-layer tentpole's measurement: full-AUC vs pAUC-DRO
    training at the SAME schedule — equal comm rounds, near-equal payload
    (pAUC-DRO ships one extra fp32 dual, the DRO temperature) — on
    imbalanced (p = 0.71) Dirichlet(0.1)-skewed shards with a planted
    hard-negative component (``DataConfig.hard_neg_frac``): 25% of the
    negatives sit nearly on top of the positives along the primary feature
    block and are only separable through a secondary block.  The full-AUC
    objective spends its gradient on the easy bulk pairs; the KL-DRO
    weighting focuses on the hard component, so at equal comm rounds
    pAUC-DRO wins on partial-AUC@FPR≤0.3 (and, here, on full AUC too — the
    hard negatives are where all the ranking errors live).  Deterministic
    seeds; the gain is asserted positive on the pAUC metric."""
    seeds = (0,) if smoke else ((0, 1) if fast else (0, 1, 2))
    Is = (8,) if (fast or smoke) else (8, 32)
    for I in Is:
        gains = []
        for seed in seeds:
            res = {}
            for obj in ("auc", "pauc_dro"):
                r = _run(8, I, stages=3, T0=48, batch=16, n_data=2048,
                         seed=seed, obj=obj, dirichlet_alpha=0.1,
                         hard_neg_frac=0.25)
                res[obj] = r
                tag = f"objective_sweep/I={I}/seed={seed}/{obj}"
                emit(f"{tag}/pauc_at_0.3", r["us_per_iter"],
                     round(r["pauc"], 4))
                emit(f"{tag}/final_auc", r["us_per_iter"], round(r["auc"], 4))
                emit(f"{tag}/comm", 0.0,
                     f"rounds={r['rounds']};payload={r['payload_bytes']};"
                     f"total_bytes={r['comm_bytes']}")
            gain = res["pauc_dro"]["pauc"] - res["auc"]["pauc"]
            gains.append(gain)
            assert res["pauc_dro"]["rounds"] == res["auc"]["rounds"]
            emit(f"objective_sweep/I={I}/seed={seed}/pauc_dro_gain", 0.0,
                 round(gain, 4))
            emit_comm(f"objective_sweep/I={I}/seed={seed}", {
                "I": I, "seed": seed, "metric": "partial_auc@fpr<=0.3",
                "pauc_dro_gain": gain,
                **{o: {"pauc": res[o]["pauc"], "auc": res[o]["auc"],
                       "rounds": res[o]["rounds"],
                       "payload_bytes": res[o]["payload_bytes"],
                       "comm_bytes": res[o]["comm_bytes"]}
                   for o in ("auc", "pauc_dro")},
            })
        # the acceptance criterion: pAUC-DRO > full-AUC on the partial-AUC
        # metric at equal comm rounds, averaged over the (deterministic)
        # seed set — a single seed at the longest interval can sit on the
        # noise floor, the mean must not
        mean_gain = float(np.mean(gains))
        assert mean_gain > 0, (I, gains)
        emit(f"objective_sweep/I={I}/mean_pauc_dro_gain", 0.0,
             round(mean_gain, 4))


def bench_window_step(fast=False, smoke=False):
    key = jax.random.PRNGKey(0)
    K = 4
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7)
    dcfg = DataConfig(kind="features", n_features=32)
    state = coda.init_state(key, MCFG, ccfg)
    from repro.data.synthetic import sample_online
    for I in [1, 8]:
        wb = sample_online(key, dcfg, (I, K, 32))
        step = jax.jit(lambda st, wb: coda.window_step(MCFG, ccfg, st, wb, 0.1))
        jax.block_until_ready(step(state, wb))
        t0 = time.time()
        n = 10
        for _ in range(n):
            jax.block_until_ready(step(state, wb))
        us = (time.time() - t0) / n * 1e6
        emit(f"window_step/I={I}/us_per_window", us,
             f"us_per_iter={us / I:.0f}")


def bench_moe_dispatch(fast=False, smoke=False):
    """The sorted-dispatch tentpole's measurement: one MoE block forward at
    eval under ``dispatch="sorted"`` (argsort + ragged grouped GEMM over a
    [T·k, d] buffer) vs ``dispatch="capacity"`` (padded scatter through the
    static dropless [E, C=T, d] buffer) — wall-clock, analytic dispatch
    buffer bytes, and the compiled module's peak temp bytes, with matching
    outputs (routing is bitwise-shared by construction — both modes consume
    the same ``moe.route`` output, so output equality is the evidence the
    dispatch plumbing preserves the decisions).  Smoke-config
    shapes run live on CPU; the real dbrx/arctic configs get analytic rows
    (the acceptance bound: sorted ≥ E/(2·top_k)× smaller at eval).

    Wall-clock caveat, same spirit as overlap_window's: the smoke configs
    keep E = 4 experts, where capacity C=T wastes only E/top_k = 2× the
    FLOPs and the sort/scatter overhead can win on tiny CPU shapes — the
    ``wide-32e`` row (E = 32, the regime the real 128-expert arctic is in)
    is where the crossover shows even on CPU; the buffer-bytes columns are
    shape-exact everywhere."""
    import dataclasses

    from repro.configs import SHAPES, get_config, get_smoke_config
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models import moe as MOE
    key = jax.random.PRNGKey(0)
    Ts = [256, 1024] if (fast or smoke) else [512, 4096, 16384]
    wide = ModelConfig(name="wide-32e", family="moe", n_layers=1,
                       d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                       vocab_size=64, moe=MoEConfig(n_experts=32, top_k=2))
    for arch, cfg in (("dbrx-132b", get_smoke_config("dbrx-132b")),
                      ("arctic-480b", get_smoke_config("arctic-480b")),
                      ("wide-32e", wide)):
        p = MOE.init_moe(key, cfg)
        for T in Ts:
            x = jax.random.normal(key, (1, T, cfg.d_model), jnp.float32) * 0.5
            tag = f"moe_dispatch/{arch}/T={T}"
            outs, rec = {}, {}
            for mode in ("sorted", "capacity"):
                c = dataclasses.replace(
                    cfg, moe=dataclasses.replace(cfg.moe, dispatch=mode))
                f = jax.jit(lambda p, x, c=c: MOE.apply_moe(c, p, x)[0])
                us = _time(f, p, x, n=3 if smoke else 10)
                outs[mode] = f(p, x)
                buf = MOE.dispatch_buffer_bytes(c, T, mode=mode)
                mem = f.lower(p, x).compile().memory_analysis()
                peak = getattr(mem, "temp_size_in_bytes", None)
                emit(f"{tag}/{mode}_us", us,
                     f"dispatch_buffer_bytes={buf};peak_temp_bytes={peak}")
                rec[mode] = {"us": us, "dispatch_buffer_bytes": buf,
                             "peak_temp_bytes": peak}
            # acceptance: both modes consume the same moe.route output
            # (bitwise-shared by construction), so matching outputs are the
            # evidence the dispatch plumbing preserves the decisions
            err = float(jnp.max(jnp.abs(outs["sorted"] - outs["capacity"])))
            assert err < 1e-4, (tag, err)
            ratio = (rec["capacity"]["dispatch_buffer_bytes"]
                     / rec["sorted"]["dispatch_buffer_bytes"])
            emit(f"{tag}/buffer_ratio", 0.0,
                 f"capacity/sorted={ratio:.1f};max_out_err={err:.1e}")
            emit_comm(tag, {"arch": arch, "T": T,
                            "routing_shared_by_construction": True,
                            "max_out_err": err, **rec})

    # analytic accounting for the REAL configs at the eval shapes — the
    # [E, T, d] vs [T·k, d] gap the smoke configs (E = 4) understate
    for arch in ("dbrx-132b", "arctic-480b"):
        rcfg = get_config(arch)
        E, k = rcfg.moe.n_experts, rcfg.moe.top_k
        for shape in ("prefill_32k", "decode_32k"):
            T = MOE.tokens_per_forward(SHAPES[shape])
            s = MOE.dispatch_buffer_bytes(rcfg, T, mode="sorted",
                                          dtype=jnp.bfloat16)
            c = MOE.dispatch_buffer_bytes(rcfg, T, mode="capacity",
                                          dtype=jnp.bfloat16)
            assert c / s >= E / (2 * k), (arch, shape, c / s)
            emit(f"moe_dispatch/real/{arch}/{shape}/buffer_ratio", 0.0,
                 f"capacity_bytes={c};sorted_bytes={s};ratio={c / s:.0f}x"
                 f";bound_E_over_2k={E / (2 * k):.0f}x")
            emit_comm(f"moe_dispatch/real/{arch}/{shape}", {
                "arch": arch, "shape": shape, "tokens": T,
                "capacity_bytes": c, "sorted_bytes": s, "ratio": c / s,
                "acceptance_bound": E / (2 * k),
            })


# --------------------------------------------------------------------------
# serving (continuous-batching engine under synthetic load)
# --------------------------------------------------------------------------
def bench_streaming_metrics(fast=False, smoke=False):
    """The streaming-metrics tentpole's measurement: sketch error vs the
    exact oracle, and bytes held vs scores seen.

    One seed-deterministic score stream (well-separated Gaussian mixture,
    ~10k+ scores) is pushed through ``SketchMetric`` at a dyadic bins sweep
    and through the materialise-everything ``ExactMetric`` oracle.
    Acceptance, asserted here, for both AUC and pAUC@FPR<=0.3:

      * |sketch − exact| <= resolution(state) + 1e-6 at every size (the
        1e-6 absorbs the f32 noise of the oracle itself — the documented
        bound is vs the true value, which f32 ``roc_auc`` only approximates
        to ~1e-7);
      * the resolution bound is monotone non-increasing under dyadic bin
        refinement;
      * merging 8 per-shard sketches (either association order) is bitwise
        identical to sketching the stream in one pass;
      * sketch state stays O(bins) while the exact state grows O(n).
    """
    rng = np.random.RandomState(0)
    n = 12_000 if (smoke or fast) else 50_000
    labels = (rng.uniform(size=n) < 0.7).astype(np.float32)
    scores = np.where(labels > 0.5, rng.normal(0.9, 1.1, n),
                      rng.normal(-0.7, 1.0, n)).astype(np.float32)

    record = {"n": n, "beta": 0.3, "sweep": []}
    for kind in ("auc", "pauc"):
        exact = SM.make_metric(kind, "exact")
        st_ex = exact.update(exact.init(), scores, labels)
        truth = exact.finalize(st_ex)
        bounds = []
        for bins in ([64, 256, 1024] if (smoke or fast)
                     else [64, 256, 1024, 4096]):
            met = SM.make_metric(kind, "sketch", bins=bins)
            t0 = time.time()
            sk = met.update(met.init(), scores, labels)
            us = (time.time() - t0) * 1e6
            val, res = met.finalize(sk), met.resolution(sk)
            err = abs(val - truth)
            assert err <= res + 1e-6, \
                f"{kind}@{bins}: err {err:.2e} > bound {res:.2e}"
            bounds.append(res)
            emit(f"streaming_metrics/{kind}/bins{bins}", us,
                 f"value={val:.4f};exact={truth:.4f};err={err:.2e};"
                 f"bound={res:.2e};state_bytes={met.state_bytes(sk)};"
                 f"exact_bytes={exact.state_bytes(st_ex)};n={n}")
            record["sweep"].append(
                {"kind": kind, "bins": bins, "value": val, "exact": truth,
                 "err": err, "bound": res,
                 "state_bytes": met.state_bytes(sk),
                 "exact_bytes": exact.state_bytes(st_ex)})
        assert all(b2 <= b1 + 1e-12 for b1, b2 in zip(bounds, bounds[1:])), \
            f"{kind}: bound not monotone under refinement: {bounds}"

    # merge-of-shards == one-stream, any association order
    met = SM.make_metric("auc", "sketch", bins=512)
    whole = met.update(met.init(), scores, labels)
    shards = [met.update(met.init(), s, l)
              for s, l in zip(np.array_split(scores, 8),
                              np.array_split(labels, 8))]
    left = shards[0]
    for s in shards[1:]:
        left = met.merge(left, s)
    right = shards[-1]
    for s in reversed(shards[:-1]):
        right = met.merge(s, right)
    ok = (np.array_equal(left.pos, whole.pos)
          and np.array_equal(left.neg, whole.neg)
          and np.array_equal(right.pos, whole.pos)
          and np.array_equal(right.neg, whole.neg))
    assert ok, "merge-of-shards diverged from the one-stream sketch"
    emit("streaming_metrics/merge_shards", 0.0,
         f"shards=8;bitwise_identical={ok};bins=512")
    record["merge_shards_identical"] = ok
    emit_comm("streaming_metrics", record)


def bench_serve_load(fast=False, smoke=False):
    """The serving tentpole's measurement: the continuous-batching engine
    under synthetic traces.

    (a) ``batch`` trace (everything arrives at t=0 — engine-bound):
        batched chunked prefill (``prefill_chunk=8``) vs the old
        token-per-tick behaviour (``prefill_chunk=1``) on the SAME trace.
        Acceptance, asserted here: per-request generated tokens are
        bitwise identical (the masked chunk step is an exact batching of
        ``serve_step``) and the chunked engine wins on tokens/s.  Both
        engines are warmed first so the comparison times steady-state
        serving, not compilation (``engine._chunk_step`` is module-level
        jit — same (cfg, shapes, chunk) reuses the compiled programs).
    (b) ``poisson`` arrivals at a fixed rate with the prefix cache on and
        a shared-prefix prompt pool — the latency-percentile rows.
    (c) ``bursty`` arrivals — tail-latency under admission pressure.
    (d) ``poisson`` arrivals with a labeled trace and a streaming-AUC
        sketch on the engine: the ``streaming_auc`` row lands in the JSON
        artifact next to the latency percentiles, asserted here to agree
        with the exact metric over the same served (score, label) pairs
        within the sketch's resolution bound.

    Every trace emits p50/p99 TTFT, p50/p99 completion latency and
    tokens/s rows plus a structured record for the JSON artifact."""
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.serving import loadgen as LG
    from repro.serving.engine import Request, ServingEngine

    arch = "stablelm-1.6b"
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    SLOTS, MAX_LEN, CHUNK = 4, 64, 8
    n = 8 if smoke else (12 if fast else 24)

    def engine(chunk, **kw):
        return ServingEngine(cfg, params, slots=SLOTS, max_len=MAX_LEN,
                             prefill_chunk=chunk, **kw)

    def rows(tag, m, extra=""):
        emit(f"{tag}/tokens_per_s", 0.0, round(m["tokens_per_s"], 1))
        emit(f"{tag}/ttft_ms", 0.0,
             f"p50={m['ttft_p50_ms']:.1f};p99={m['ttft_p99_ms']:.1f}")
        emit(f"{tag}/latency_ms", 0.0,
             f"p50={m['latency_p50_ms']:.1f};p99={m['latency_p99_ms']:.1f}")
        emit(f"{tag}/served", 0.0,
             f"completed={m['completed']}/{m['n_requests']};"
             f"ticks={m['ticks']};prefilled={m['tokens_prefilled']};"
             f"decoded={m['tokens_decoded']}{extra}")

    # warm both compiled programs (C ∈ {1, CHUNK}) before any timing
    for chunk in (1, CHUNK):
        e = engine(chunk)
        e.add_request(Request(uid=-1, prompt=list(range(1, 12)),
                              max_new_tokens=2))
        e.run()

    # (a) chunked prefill vs token-per-tick, bitwise-identical outputs
    batch_kw = dict(kind="batch", n_requests=n, prompt_len=(24, 57),
                    max_new=(2, 5), seed=3)
    res = {}
    for label, chunk in (("token_per_tick", 1), ("chunked", CHUNK)):
        eng = engine(chunk)
        trace = LG.make_trace(LG.TraceConfig(**batch_kw), cfg.vocab_size)
        reqs, wall = LG.run_trace(eng, trace)
        res[label] = (reqs, LG.summarize(reqs, wall, eng))
        rows(f"serve_load/batch/{label}", res[label][1])
    toks_equal = all(
        a.generated == b.generated
        for a, b in zip(res["token_per_tick"][0], res["chunked"][0]))
    speedup = (res["chunked"][1]["tokens_per_s"]
               / res["token_per_tick"][1]["tokens_per_s"])
    # the acceptance criteria: exact batching, and batching must pay
    assert toks_equal, "chunked prefill diverged from token-per-tick"
    assert speedup > 1.0, f"chunked prefill did not win: {speedup:.3f}x"
    emit("serve_load/batch/chunked_speedup", 0.0,
         f"{speedup:.2f}x;tokens_identical={toks_equal}")

    # (b) poisson arrivals + prefix cache over a shared-prefix prompt pool
    # (c) bursty arrivals
    paced = [("poisson", dict(kind="poisson", rate=48.0, n_requests=n,
                              prompt_len=(16, 49), max_new=(2, 6),
                              prefix_pool=2, prefix_len=16, seed=1),
              dict(prefix_cache_size=8)),
             ("bursty", dict(kind="bursty", rate=32.0, burst_size=SLOTS * 2,
                             n_requests=n, prompt_len=(16, 49),
                             max_new=(2, 6), seed=2), {})]
    for name, trace_kw, eng_kw in paced:
        eng = engine(CHUNK, **eng_kw)
        trace = LG.make_trace(LG.TraceConfig(**trace_kw), cfg.vocab_size)
        reqs, wall = LG.run_trace(eng, trace)
        m = LG.summarize(reqs, wall, eng)
        extra = (f";prefix_hits={m['prefix_hits']}"
                 f";prefix_misses={m['prefix_misses']}"
                 if eng_kw.get("prefix_cache_size") else "")
        rows(f"serve_load/{name}", m, extra)
        emit_comm(f"serve_load/{name}", {
            "arch": arch, "knobs": {"slots": SLOTS, "max_len": MAX_LEN,
                                    "prefill_chunk": CHUNK, **eng_kw},
            "trace": trace_kw, "metrics": m})
    emit_comm("serve_load/batch", {
        "arch": arch,
        "knobs": {"slots": SLOTS, "max_len": MAX_LEN},
        "trace": batch_kw,
        "chunked_speedup": speedup, "tokens_identical": toks_equal,
        "metrics": {label: r[1] for label, r in res.items()}})

    # (d) labeled poisson trace: streaming AUC over served traffic
    met = SM.make_metric("auc", "sketch", bins=512)
    eng = engine(CHUNK, metric=met)
    labeled_kw = dict(kind="poisson", rate=48.0, n_requests=n,
                      prompt_len=(8, 33), max_new=(2, 5), labeled=True,
                      seed=4)
    trace = LG.make_trace(LG.TraceConfig(**labeled_kw), cfg.vocab_size)
    reqs, wall = LG.run_trace(eng, trace)
    m = LG.summarize(reqs, wall, eng)
    assert "streaming_auc" in m, "labeled trace produced no streaming row"
    sl = [(r.score, r.label) for r in reqs
          if r.score is not None and r.label is not None]
    ex = SM.make_metric("auc", "exact").compute(
        np.asarray([s for s, _ in sl], np.float32),
        np.asarray([l for _, l in sl], np.float32))
    err = abs(m["streaming_auc"] - ex)
    assert err <= m["streaming_resolution"] + 1e-6, \
        f"served sketch AUC off by {err:.2e} > {m['streaming_resolution']:.2e}"
    rows("serve_load/labeled", m)
    emit("serve_load/labeled/streaming_auc", 0.0,
         f"auc={m['streaming_auc']:.4f};exact={ex:.4f};"
         f"res={m['streaming_resolution']:.2e};"
         f"scored={m['streaming_scored']};"
         f"state_bytes={m['streaming_state_bytes']}")
    emit_comm("serve_load/labeled", {
        "arch": arch, "knobs": {"slots": SLOTS, "max_len": MAX_LEN,
                                "prefill_chunk": CHUNK,
                                "metric_backend": "sketch"},
        "trace": labeled_kw, "metrics": m})


# --------------------------------------------------------------------------
# roofline (deliverable g — reads the dry-run artifacts)
# --------------------------------------------------------------------------
def bench_roofline(fast=False, smoke=False):
    files = sorted(glob.glob(os.path.join(ARTIFACTS, "*.json")))
    if not files:
        emit("roofline/no_artifacts", 0.0,
             "run `python -m repro.launch.dryrun --all --both-meshes` first")
        return
    for f in files:
        rec = json.load(open(f))
        tag = os.path.basename(f)[:-5]
        if rec.get("status") != "ok":
            emit(f"roofline/{tag}", 0.0, rec.get("status"))
            continue
        terms = H.roofline_terms(rec["flops"], rec["hbm_bytes"],
                                 rec["collectives"]["total_bytes"], 1)
        model_flops = _model_flops(rec)
        ratio = model_flops / max(rec["flops"] * rec["n_chips"], 1)
        emit(f"roofline/{tag}",
             max(terms["compute_s"], terms["memory_s"],
                 terms["collective_s"]) * 1e6,
             f"bottleneck={terms['bottleneck']};c={terms['compute_s']:.2e}"
             f";m={terms['memory_s']:.2e};x={terms['collective_s']:.2e}"
             f";useful_ratio={ratio:.2f}")


def _model_flops(rec: dict) -> float:
    """6·N·D (train), 2·N·D (prefill/decode); active params for MoE."""
    n = rec["n_params_active"]
    d = rec["tokens_per_step"] * rec.get("window_steps", 1)
    mult = 6.0 if rec["step_kind"] == "coda_window" else 2.0
    return mult * n * d


BENCHES = {
    "table1": bench_table1,
    "vary_k": bench_vary_k,
    "vary_i": bench_vary_i,
    "tradeoff": bench_tradeoff,
    "growing_i": bench_growing_i,
    "kernels": bench_kernels,
    "window_step": bench_window_step,
    "sharded_window": bench_sharded_window,
    "overlap_window": bench_overlap_window,
    "hetero_window": bench_hetero_window,
    "optimizer_window": bench_optimizer_window,
    "fault_tolerance": bench_fault_tolerance,
    "objective_sweep": bench_objective_sweep,
    "moe_dispatch": bench_moe_dispatch,
    "streaming_metrics": bench_streaming_metrics,
    "serve_load": bench_serve_load,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI tier: tiny T/I/batch, fixed seed, < 2 min on "
                         "CPU (implies --fast)")
    ap.add_argument("--json", default="",
                    help="dump emitted rows + structured comm-accounting "
                         "records to this path (the CI artifact)")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    help="split the CPU host into N XLA devices before the "
                         "backend initialises (for --only sharded_window)")
    args = ap.parse_args()
    if args.force_host_devices:
        from repro.launch import mesh as MESH
        MESH.force_host_device_count(args.force_host_devices)
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        fn(fast=args.fast or args.smoke, smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [{"name": n, "us_per_call": u, "derived": d}
                                for n, u, d in ROWS],
                       "comm": COMM}, f, indent=2, default=str)
        print(f"wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
