"""End-to-end driver: CoDA-train a ~100M-parameter dense transformer scorer
for a few hundred steps on synthetic imbalanced sequence data.

The model is a qwen-family decoder (d=768, 12 layers, GQA 12:4, vocab 8192 ≈
101M params) — big enough that the worker-stacked CoDA state and the
I-window scan exercise exactly the code paths the production mesh runs,
small enough that CPU makes progress.  Expect a few seconds/step on CPU.

    PYTHONPATH=src python examples/train_100m.py --steps 200 --workers 2
"""
import argparse
import dataclasses
import sys
import time

import jax

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import coda, objective, schedules
from repro.data import DataConfig, ShardedDataset
from repro.models import count_params, model as M


def build_config():
    base = get_config("qwen2.5-14b")
    return dataclasses.replace(
        base, name="qwen-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=2048, vocab_size=8192, head_dim=0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--interval", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--eval-n", type=int, default=256)
    args = ap.parse_args()

    mcfg = build_config()
    object.__setattr__(mcfg, "head_dim", mcfg.d_model // mcfg.n_heads)
    n = count_params(mcfg)
    print(f"model: {mcfg.name}, {n / 1e6:.1f}M params, "
          f"K={args.workers}, I={args.interval}")

    key = jax.random.PRNGKey(0)
    dcfg = DataConfig(kind="tokens", vocab_size=mcfg.vocab_size,
                      seq_len=args.seq, signal=1.0)
    ds = ShardedDataset(key, dcfg, 4096, args.workers, target_p=0.71)
    ccfg = coda.CoDAConfig(n_workers=args.workers, p_pos=ds.p_pos)
    stages = max(1, args.steps * args.workers // 256)
    sched = schedules.ScheduleConfig(
        n_workers=args.workers, eta0=0.2,
        T0=max(args.interval, args.steps // max(stages, 1)),
        I0=args.interval)

    test = ds.full(args.eval_n)

    def auc(state):
        params0 = jax.tree_util.tree_map(lambda x: x[0], state["params"])
        h, _ = M.score(mcfg, params0, {"tokens": test["tokens"]})
        return float(objective.roc_auc(h, test["labels"]))

    t0 = time.time()
    res = coda.fit(
        key, mcfg, ccfg, sched, n_stages=stages,
        sample_window=lambda k, i: ds.sample_window(k, i, args.batch),
        sample_alpha_batch=lambda k, m: ds.sample_alpha_batch(k, min(m, 64)))
    dt = time.time() - t0

    print(f"trained {res.iterations} iterations in {dt / 60:.1f} min "
          f"({dt / max(res.iterations, 1):.2f} s/iter)")
    print(f"communication rounds: {res.comm_rounds} "
          f"(I=1 naive parallel: {res.iterations + stages})")
    print(f"final test AUC: {auc(res.state):.4f}")
    losses = [l for (_, _, l) in res.history]
    print(f"loss: first5={sum(losses[:5]) / 5:.4f} "
          f"last5={sum(losses[-5:]) / 5:.4f}")


if __name__ == "__main__":
    main()
