"""Batched serving example: continuous batching over KV-cache slots.

Loads a reduced stablelm-family model, submits a mixed bag of requests
(different prompt lengths / generation budgets), and serves them through the
engine's prefill + greedy-decode loop.

    PYTHONPATH=src python examples/serve_requests.py [--arch hymba-1.5b]
"""
import argparse
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    mcfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), mcfg)
    eng = ServingEngine(mcfg, params, slots=args.slots, max_len=128)

    rng = np.random.RandomState(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.randint(0, mcfg.vocab_size,
                             size=rng.randint(3, 20)).tolist()
        r = Request(uid=i, prompt=prompt,
                    max_new_tokens=int(rng.randint(4, 12)))
        reqs.append(r)
        eng.add_request(r)

    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    for r in reqs:
        print(f"  req {r.uid:2d}: prompt len {len(r.prompt):2d} -> "
              f"{len(r.generated)} tokens "
              f"(ttft {r.ttft * 1e3:6.1f} ms, score {r.score:+.3f}): "
              f"{r.generated}")
    n = sum(len(r.generated) for r in reqs)
    print(f"\nserved {len(reqs)} requests / {n} tokens in {dt:.2f}s "
          f"({n / dt:.1f} tok/s on CPU, arch={mcfg.name})")


if __name__ == "__main__":
    main()
