"""Quickstart: distributed AUC maximization with CoDA in ~1 minute on CPU.

Builds an imbalanced synthetic dataset (p = 0.71, the paper's setting),
partitions it across K = 4 simulated workers (each worker only ever touches
its own shard, exactly like Algorithm 1), and runs 3 proximal-point stages of
CoDA with communication every I = 8 local steps.

    PYTHONPATH=src python examples/quickstart.py

Heterogeneous data
------------------
The even split above makes every shard look like the global distribution —
the homogeneity CoDA's analysis assumes.  Real partitions are skewed; pass
``dirichlet_alpha`` to ``ShardedDataset`` for Dirichlet(α) label skew (small
α = some workers see almost no positives) and switch the algorithm to
CODASCA (``CoDAConfig(algorithm="codasca")``, core/codasca.py), whose
control variates cancel the local drift at the same one all-reduce per
window (2x payload).  The full launcher exposes both:

    PYTHONPATH=src python -m repro.launch.train --arch mlp --workers 8 \\
        --algorithm codasca --dirichlet-alpha 0.1 --stages 3 --interval 16

and ``python -m benchmarks.run --only hetero_window`` sweeps CoDA vs
CODASCA over α ∈ {0.1, 1, ∞} × I ∈ {4, 16, 64} at equal comm rounds.

Overlapped averaging
--------------------
On the shard_map executor the per-window all-reduce normally blocks the
critical path.  ``--overlap`` (``CoDAConfig(overlap_chunks=C)``) reschedules
it: windows run as fused pairs and each averaging lowers as C ppermute
ring chains per dtype bucket, so the first window's wire time can hide
under the second window's compute — same mean, same bytes, asserted
against the compiled HLO:

    PYTHONPATH=src python -m repro.launch.train --arch mlp --workers 8 \\
        --executor shard_map --force-host-devices 8 --overlap \\
        --overlap-chunks 4 --stages 2 --interval 4

``python -m benchmarks.run --only overlap_window --force-host-devices 8``
compares the overlapped and blocking schedules at equal comm bytes.
"""
import sys

import jax

sys.path.insert(0, "src")

from repro.configs.base import mlp_config
from repro.core import coda, objective, schedules
from repro.data import DataConfig, ShardedDataset
from repro.models import model as M

K, I, BATCH = 4, 8, 32


def main():
    key = jax.random.PRNGKey(0)
    mcfg = mlp_config(n_features=32, d=64)
    dcfg = DataConfig(kind="features", n_features=32, signal=1.5)
    ds = ShardedDataset(key, dcfg, 8192, K, target_p=0.71)
    print(f"dataset: n={ds.n}, positive ratio={ds.p_pos:.3f}, {K} workers")

    ccfg = coda.CoDAConfig(n_workers=K, p_pos=ds.p_pos)
    sched = schedules.ScheduleConfig(n_workers=K, eta0=0.5, T0=64, I0=I)

    test = ds.full(2048)

    def auc(state):
        params0 = jax.tree_util.tree_map(lambda x: x[0], state["params"])
        h, _ = M.score(mcfg, params0, {"features": test["features"]})
        return float(objective.roc_auc(h, test["labels"]))

    res = coda.fit(
        key, mcfg, ccfg, sched, n_stages=3,
        sample_window=lambda k, i: ds.sample_window(k, i, BATCH),
        sample_alpha_batch=lambda k, m: ds.sample_alpha_batch(k, m))

    print(f"iterations            : {res.iterations}")
    print(f"communication rounds  : {res.comm_rounds} "
          f"(naive parallel would need {res.iterations + 3})")
    print(f"bytes/round/worker    : {coda.model_bytes(res.state):,}")
    print(f"final test AUC        : {auc(res.state):.4f}")
    assert auc(res.state) > 0.85


if __name__ == "__main__":
    main()
