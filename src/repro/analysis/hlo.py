"""Compiled-artifact analysis: collective bytes from optimized HLO text and
the three roofline terms (§Roofline of EXPERIMENTS.md).

collective_bytes is NOT in cost_analysis(); we parse the optimized HLO and
sum the result-shape bytes of every cross-device op.  ``collective_ops``
keeps the per-op records (kind, per-dtype bytes, replica groups) so tests
can verify the *count* and *payload dtype* of what actually crosses the
wire — e.g. that one CoDA window lowers to exactly one all-reduce of
``model_bytes`` operand bytes, or that the int8-compressed averaging ships
an s8 payload (tests/test_coda_sharded.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<type>\([^)]*\)|[\w\[\],]+(?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


_GROUPS_RE = re.compile(r"replica_groups=(\{\{.*?\}\}|\{[^{}]*\})")


def _dtype_bytes(type_str: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[dt] = out.get(dt, 0) + n * _DTYPE_BYTES[dt]
    return out


def _shape_bytes(type_str: str) -> int:
    return sum(_dtype_bytes(type_str).values())


def collective_ops(hlo_text: str) -> List[dict]:
    """One record per collective op in the optimized HLO:
    {op, bytes, by_dtype, replica_groups}.  ``bytes`` are result-shape bytes
    (== per-participant operand bytes for all-reduce; the gathered size for
    all-gather).  ``replica_groups`` is the literal group string, so callers
    can tell cross-worker reductions apart from any intra-group ones."""
    ops = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        g = _GROUPS_RE.search(line)
        by_dtype = _dtype_bytes(m.group("type"))
        ops.append({
            "op": m.group("op"),
            "bytes": sum(by_dtype.values()),
            "by_dtype": by_dtype,
            "replica_groups": g.group(1) if g else "",
        })
    return ops


def verify_window_payload(hlo_text: str, expected_bytes: int, *,
                          op: str = "all-reduce",
                          count: int = 1) -> List[dict]:
    """Assert a compiled CoDA/CODASCA window's wire traffic: exactly
    ``count`` collectives, all of kind ``op``, totalling ``expected_bytes``
    result-shape bytes — and *no other* collective of any kind.

    The expected payload comes from ``coda.window_payload_bytes``:
    ``model_bytes`` for a CoDA window, ``2 ×`` that for CODASCA (state +
    control variates in one bucket).  Returns the op records on success so
    callers can additionally inspect dtypes / replica groups.
    """
    ops = collective_ops(hlo_text)
    stray = [o for o in ops if o["op"] != op]
    if stray:
        raise AssertionError(
            f"expected only {op} ops, found {[(o['op'], o['bytes']) for o in stray]}")
    if len(ops) != count:
        raise AssertionError(
            f"expected exactly {count} {op} op(s), found "
            f"{[(o['op'], o['bytes']) for o in ops]}")
    total = sum(o["bytes"] for o in ops)
    if total != expected_bytes:
        raise AssertionError(
            f"window payload mismatch: HLO ships {total} bytes, accounting "
            f"says {expected_bytes} ({[(o['op'], o['bytes']) for o in ops]})")
    return ops


def collective_bytes(hlo_text: str) -> Dict[str, dict]:
    """Per-collective-kind {bytes, count, by_dtype} from optimized HLO."""
    out = {k: {"bytes": 0, "count": 0, "by_dtype": {}} for k in _COLLECTIVES}
    for rec in collective_ops(hlo_text):
        kind = out[rec["op"]]
        kind["bytes"] += rec["bytes"]
        kind["count"] += 1
        for dt, b in rec["by_dtype"].items():
            kind["by_dtype"][dt] = kind["by_dtype"].get(dt, 0) + b
    out["total_bytes"] = sum(v["bytes"] for v in out.values() if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for v in out.values() if isinstance(v, dict))
    return out


# --------------------------------------------------------------------------
# roofline
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Hardware:
    """v5e-class chip (the production target)."""
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link (~3 links usable/chip)


V5E = Hardware()


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int, hw: Hardware = V5E) -> dict:
    """The three §Roofline terms, in seconds.

    flops / hbm_bytes are whole-program HLO numbers (cost_analysis of the
    partitioned module is already per-device under GSPMD; we pass
    per_device=True from the dry-run and n_chips=1 here accordingly —
    see launch/dryrun.py).
    """
    compute = flops / (n_chips * hw.peak_flops)
    memory = hbm_bytes / (n_chips * hw.hbm_bw)
    collective = coll_bytes / (n_chips * hw.ici_bw)
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    return terms
