"""Compiled-artifact analysis: collective bytes from optimized HLO text and
the three roofline terms (§Roofline of EXPERIMENTS.md).

collective_bytes is NOT in cost_analysis(); we parse the optimized HLO and
sum the result-shape bytes of every cross-device op.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<type>\([^)]*\)|[\w\[\],]+(?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, dict]:
    """Per-collective-kind {bytes, count} from optimized HLO text."""
    out = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        kind = m.group("op")
        out[kind]["bytes"] += _shape_bytes(m.group("type"))
        out[kind]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for v in out.values() if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for v in out.values() if isinstance(v, dict))
    return out


# --------------------------------------------------------------------------
# roofline
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Hardware:
    """v5e-class chip (the production target)."""
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link (~3 links usable/chip)


V5E = Hardware()


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int, hw: Hardware = V5E) -> dict:
    """The three §Roofline terms, in seconds.

    flops / hbm_bytes are whole-program HLO numbers (cost_analysis of the
    partitioned module is already per-device under GSPMD; we pass
    per_device=True from the dry-run and n_chips=1 here accordingly —
    see launch/dryrun.py).
    """
    compute = flops / (n_chips * hw.peak_flops)
    memory = hbm_bytes / (n_chips * hw.hbm_bw)
    collective = coll_bytes / (n_chips * hw.ici_bw)
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    return terms
