"""Compiled-artifact analysis: collective bytes from optimized HLO text and
the three roofline terms (see docs/analysis.md).

collective_bytes is NOT in cost_analysis(); we parse the optimized HLO and
sum the result-shape bytes of every cross-device op.  ``collective_ops``
keeps the per-op records (kind, per-dtype bytes, replica groups) so tests
can verify the *count* and *payload dtype* of what actually crosses the
wire — e.g. that one CoDA window lowers to exactly one all-reduce of
``model_bytes`` operand bytes, or that the int8-compressed averaging ships
an s8 payload (tests/test_coda_sharded.py).

The expected payloads come from the generic tree accounting
(``coda.model_bytes`` / ``coda.window_payload_by_dtype``: every params leaf
+ every leaf of the objective's dual tree, core/objective.py) — nothing
here or there names a dual field, so the asserts hold for any registered
objective's layout (AUC's 3 scalars, pAUC-DRO's 4, BCE's none).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# Matches the op name right after the result type.  The optional "-start"
# suffix is captured so async collectives count ONCE, from their start op:
# the matching "-done" line does not match at all (the regex requires "("
# directly after the op name / "-start", and "-done(" has neither) — a
# property tests/test_hlo_parser.py pins.  Tuple result types may nest
# parens (multi-operand async collectives), hence the non-greedy paren
# matcher with a bounded nesting depth of one.
_OP_RE = re.compile(
    r"=\s*(?P<type>\((?:[^()]|\([^()]*\))*\)|[\w\[\],]+(?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(")


_GROUPS_RE = re.compile(r"replica_groups=(\{\{.*?\}\}|\{[^{}]*\})")


def _dtype_bytes(type_str: str) -> dict[str, int]:
    out: dict[str, int] = {}
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[dt] = out.get(dt, 0) + n * _DTYPE_BYTES[dt]
    return out


def _shape_bytes(type_str: str) -> int:
    return sum(_dtype_bytes(type_str).values())


def _tuple_components(type_str: str) -> list[str]:
    """Split a tuple type string at its TOP-LEVEL commas — one nesting level
    deep, matching _OP_RE's type matcher.  Non-tuple types come back as a
    single component."""
    s = type_str.strip()
    if not (s.startswith("(") and s.endswith(")")):
        return [s]
    parts, depth, cur = [], 0, []
    for ch in s[1:-1]:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


def collective_ops(hlo_text: str) -> list[dict]:
    """One record per collective op in the optimized HLO:
    {op, bytes, by_dtype, replica_groups}.  ``bytes`` are result-shape bytes
    (== per-participant operand bytes for all-reduce; the gathered size for
    all-gather).  ``replica_groups`` is the literal group string, so callers
    can tell cross-worker reductions apart from any intra-group ones.

    Async pairs count ONCE: the ``-start`` op is the record (only the
    RESULT component of its (operands, results) tuple type is summed — the
    operand alias would double the bytes) and the ``-done`` line never
    matches."""
    ops = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        g = _GROUPS_RE.search(line)
        type_str = m.group("type")
        if m.group("start"):
            parts = _tuple_components(type_str)
            if len(parts) >= 2:
                type_str = parts[1]
        by_dtype = _dtype_bytes(type_str)
        ops.append({
            "op": m.group("op"),
            "bytes": sum(by_dtype.values()),
            "by_dtype": by_dtype,
            "replica_groups": g.group(1) if g else "",
        })
    return ops


def verify_window_payload(hlo_text: str, expected_bytes: int, *,
                          op: str = "all-reduce",
                          count: int = None,
                          by_dtype: dict[str, int] = None,
                          baseline_bytes: int = None,
                          delta_bytes: int = None,
                          opt_bytes: int = None) -> list[dict]:
    """Assert a compiled CoDA/CODASCA window's wire traffic: all collectives
    are of kind ``op``, totalling ``expected_bytes`` result-shape bytes —
    and *no other* collective of any kind.

    The bucketed averaging ships ONE collective per payload *dtype bucket*
    (core/bucketing.pmean_buckets).  ``expected_bytes`` is always the
    LOGICAL payload (``coda.window_payload_bytes``: ``model_bytes`` for a
    CoDA window, ``2 ×`` that for CODASCA — state + control variates in
    one bucket).

    Three modes:
      * default (``count=None``, no ``by_dtype``) — every payload dtype
        appears in exactly one op and the wire bytes equal
        ``expected_bytes``.  The right check for single-dtype states (one
        all-reduce, exactly).
      * ``count=N`` — pin the op count instead, wire bytes still equal
        ``expected_bytes``.
      * ``by_dtype={hlo tag: bytes}`` (``coda.window_payload_by_dtype``) —
        the mixed-dtype check: each logical bucket must map to exactly one
        op, either verbatim or *float-normalized* (backends without native
        low-precision collectives, e.g. the CPU host backend, widen a
        bf16/f16 all-reduce to f32 — same element count, doubled wire
        bytes), no op may be left over, and the buckets must sum to
        ``expected_bytes``.

    ``baseline_bytes``/``delta_bytes`` (always both) additionally pin the
    payload as an exact baseline + feature delta: ``expected_bytes`` must
    equal their sum.  This is the streaming-eval assert — with the sketch
    hook off the compiled wire bytes are the baseline *unchanged*
    (``delta_bytes=0``), with it on they grow by exactly
    ``coda.streaming_payload_bytes(state)`` (2·stream_bins·4 fp32) and not
    a byte more, while the op-shape checks above still hold (the sketch
    rides the existing fp32 bucket, it does not add a collective).

    ``opt_bytes`` (``coda.opt_state_bytes``): per-worker local-optimizer
    state size.  It never changes what passes — preconditioning is strictly
    local and the state must stay off the wire — but when the shipped bytes
    exceed the expectation by exactly this amount, the failure message says
    "optimizer state leaked onto the wire" instead of a raw byte delta.

    Returns the op records on success so callers can additionally inspect
    dtypes / replica groups.

    This is the R1 collective-placement rule of the compiled-program
    auditor — the checker lives in ``analysis/audit.py``
    (``window_payload_problems``); this wrapper keeps the historical
    assert-style entry point.
    """
    from repro.analysis import audit
    return audit.assert_window_payload(
        hlo_text, expected_bytes, op=op, count=count, by_dtype=by_dtype,
        baseline_bytes=baseline_bytes, delta_bytes=delta_bytes,
        opt_bytes=opt_bytes)


_DOT_RE = re.compile(r"\b(dot|convolution)\(")
_CALLEE_RE = re.compile(r"(?:calls|body|condition|to_apply)=(%?[\w.\-]+)")
# computation headers: "%name (params...) -> type {" / "ENTRY %name (...)";
# the param list may nest parens (tuple types), so don't try to match it
_COMPUTATION_HDR_RE = re.compile(
    r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*->.*\{\s*$")


def _dot_bearing_computations(hlo_text: str):
    """Names of HLO computations that contain a dot/convolution, directly or
    through any computation they call (fusions, while bodies — the scanned
    local steps live inside a while loop).  This is how 'real model
    compute' is told apart from the ring's own index arithmetic."""
    direct, calls, cur = set(), {}, None
    for line in hlo_text.splitlines():
        m = _COMPUTATION_HDR_RE.match(line)
        if m and "{" in line:
            cur = m.group(1).lstrip("%")
            continue
        if cur is None:
            continue
        if _DOT_RE.search(line):
            direct.add(cur)
        for callee in _CALLEE_RE.findall(line):
            calls.setdefault(cur, set()).add(callee.lstrip("%"))
    # propagate dot-ness up the call graph to a fixed point
    changed = True
    while changed:
        changed = False
        for name, callees in calls.items():
            if name not in direct and callees & direct:
                direct.add(name)
                changed = True
    return direct


_SSA_NAME_RE = re.compile(r"(%[\w.\-]+)")


def permute_chain_components(hlo_text: str) -> int:
    """Number of INDEPENDENT collective-permute dependency chains in the
    entry computation — the falsifiable core of the overlap claim.

    Two permutes belong to one chain when one's result feeds the other
    through entry-computation dataflow (adds, fusions, slices — the ring's
    glue ops); propagation is cut at ``while``/``conditional`` calls, which
    are the window boundaries (the next window's scan consumes the whole
    averaged state, so every ring of the next window would otherwise
    spuriously merge with every ring of the previous one).  The chunked
    ring lowering must produce exactly ``bucketing.ring_chain_count``
    components per ring: a de-chunked lowering collapses them to one per
    bucket, and an artificial cross-chunk dependency (which would
    serialize the chunks and kill the overlap) merges components.

    Only meaningful when the local steps lower as a loop (I ≥ 2): an I=1
    window inlines its compute into the entry computation, and the ring-
    to-ring dependency through the inlined (dot-free, elementwise) prox
    updates legitimately merges every component into one — callers skip
    the chain check there (``verify_overlapped_window(n_chains=None)``).
    """
    lines = hlo_text.splitlines()
    start = next((i for i, ln in enumerate(lines)
                  if ln.startswith("ENTRY ")), None)
    if start is None:
        raise AssertionError("no ENTRY computation in HLO text")
    carried: dict[str, frozenset] = {}
    parent: dict[int, int] = {}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    n_roots = 0
    for raw in lines[start + 1:]:
        s = raw.strip()
        if s == "}":
            break
        if not s.startswith("%") or "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        name = lhs.strip().split()[0]
        ancestors = set()
        if " while(" not in s and " conditional(" not in s:
            for ref in _SSA_NAME_RE.findall(rhs):
                ancestors |= carried.get(ref, frozenset())
        if _OP_RE.search(s):                  # a collective-permute hop
            if not ancestors:
                rid = n_roots
                parent[rid] = rid
                n_roots += 1
            else:
                ids = {find(i) for i in ancestors}
                rid = ids.pop()
                for other in ids:
                    parent[find(other)] = find(rid)
            carried[name] = frozenset({rid})
        elif ancestors:
            carried[name] = frozenset(ancestors)
    return len({find(r) for r in range(n_roots)})


def verify_overlapped_window(hlo_text: str, *, n_hops: int,
                             n_chains: int = None,
                             require_compute_between: bool = True) -> list[dict]:
    """Assert the overlapped window-pair module's wire schedule: NO blocking
    all-reduce (or any other collective kind); the averaging is exactly
    ``n_hops`` ``collective-permute`` ops (C chunk chains × 2·(R−1) hops ×
    the rings in the module, from ``bucketing.ring_hop_count``); and, with
    ``n_chains`` (rings × ``bucketing.ring_chain_count``), that the hops
    form exactly that many INDEPENDENT dependency chains — the property
    that lets an async scheduler run late chunks' wire time under the
    compute consuming early chunks.  A de-chunked or artificially
    serialized lowering fails the chain check even though its hop count
    may survive.

    ``require_compute_between`` additionally checks that dot-bearing
    compute (the second window's matmuls) is scheduled between the first
    and last hop.  For a two-ring pair module this is a structural sanity
    check (it confirms both windows really were fused into one module
    around the averaging) rather than a scheduling guarantee — the
    falsifiable overlap invariants are the chain/hop/no-barrier checks
    above.  Returns the permute op records.

    This is the ring form of the auditor's R1 collective-placement rule —
    the checker lives in ``analysis/audit.py``
    (``overlapped_window_problems``); this wrapper keeps the historical
    assert-style entry point.
    """
    from repro.analysis import audit
    return audit.assert_overlapped_window(
        hlo_text, n_hops=n_hops, n_chains=n_chains,
        require_compute_between=require_compute_between)


def collective_bytes(hlo_text: str) -> dict[str, dict]:
    """Per-collective-kind {bytes, count, by_dtype} from optimized HLO."""
    out = {k: {"bytes": 0, "count": 0, "by_dtype": {}} for k in _COLLECTIVES}
    for rec in collective_ops(hlo_text):
        kind = out[rec["op"]]
        kind["bytes"] += rec["bytes"]
        kind["count"] += 1
        for dt, b in rec["by_dtype"].items():
            kind["by_dtype"][dt] = kind["by_dtype"].get(dt, 0) + b
    out["total_bytes"] = sum(v["bytes"] for v in out.values() if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for v in out.values() if isinstance(v, dict))
    return out


# --------------------------------------------------------------------------
# roofline
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Hardware:
    """v5e-class chip (the production target)."""
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link (~3 links usable/chip)


V5E = Hardware()


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int, hw: Hardware = V5E) -> dict:
    """The three §Roofline terms, in seconds.

    flops / hbm_bytes are whole-program HLO numbers (cost_analysis of the
    partitioned module is already per-device under GSPMD; we pass
    per_device=True from the dry-run and n_chips=1 here accordingly —
    see launch/dryrun.py).
    """
    compute = flops / (n_chips * hw.peak_flops)
    memory = hbm_bytes / (n_chips * hw.hbm_bw)
    collective = coll_bytes / (n_chips * hw.ici_bw)
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    return terms
