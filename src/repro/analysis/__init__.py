from repro.analysis import hlo  # noqa: F401
