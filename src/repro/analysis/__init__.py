from repro.analysis import audit, hlo  # noqa: F401
