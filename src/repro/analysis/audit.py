"""Compiled-program auditor: a rule engine over jaxprs + optimized HLO.

PRs 1–7 accumulated one-off compiled-artifact asserts — window-payload
checks, ring-schedule checks, payload-split checks — each re-parsing HLO
text its own way.  This module promotes them into a single static-analysis
layer: every jitted program in the repo is captured as a
:class:`CompiledProgram` record (closed jaxpr, optimized HLO text,
``cost_analysis``, input/output aliasing, compile count) and run through a
fixed rule set:

  * **R1 collective-placement** — the paper's headline claim as a static
    property: local-step bodies are collective-free; a window is exactly
    ONE bucketed all-reduce of the documented payload (or the asserted
    chunked ppermute ring schedule under ``overlap_chunks``; or the
    s8 + f32-scale all-gather pair under ``avg_compress="int8"``).  The
    historical ``analysis/hlo.verify_window_payload`` /
    ``verify_overlapped_window`` entry points are thin wrappers over the
    R1 checkers here (:func:`assert_window_payload`,
    :func:`assert_overlapped_window`).
  * **R2 donation-audit** — every buffer donated at the jit boundary is
    actually aliased in the compiled output (``input_output_alias``); a
    dropped donation silently doubles peak memory and is a hard failure.
  * **R3 host-sync/dtype lint** — a recursive jaxpr walk: no f64 creep, no
    host callbacks or device transfers inside jitted hot paths, and
    matmuls/reductions over sub-fp32 operands must accumulate in ≥ fp32.
  * **R4 recompile-budget** — callables carry a compile count (jit cache
    size) pinned against the documented budget: the serve engine compiles
    exactly two programs (C ∈ {prefill_chunk, 1}), the training executors
    compile once per distinct window length and never re-trace.
  * **R5 Pallas static checks** — tile-shape divisibility, grid bounds and
    alignment for the kernels' launch geometry (each kernel module exposes
    the ``launch_geometry`` it launches with), plus the dispatch
    invariant that interpret mode is never selectable off-TPU except via
    the explicit ``impl="pallas"`` override.

The second half of the module is the program *registry*: capture helpers
that build the records for each distinct program in the repo —
``core/coda.py``'s vmap oracle, ``core/coda_sharded.py``'s shard_map
window / fused pair / stage programs, ``serving/engine.py``'s two chunk
programs, and the ``kernels/`` launch seam.  ``scripts/audit.py`` drives
them over the full executor × algorithm × dtype × schedule matrix and
emits a JSON artifact; CI gates on it.  Rule semantics are documented in
docs/analysis.md; red-team counterexamples live in tests/test_audit.py.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp

from repro.analysis import hlo as H

# --------------------------------------------------------------------------
# records
# --------------------------------------------------------------------------
@dataclasses.dataclass
class CompiledProgram:
    """One distinct jitted program, as captured from its compiled artifact.

    ``expect`` carries the per-program rule parameters:
      * ``"collectives"`` (R1) — ``{"kind": "none"}`` |
        ``{"kind": "window", ...verify params}`` |
        ``{"kind": "ring", "n_hops": H, "n_chains": C|None}`` |
        ``{"kind": "gather_pair", "payload_bytes": B, "n_workers": K}``
      * ``"compiles"`` (R4) — ``{"exact": N}`` or ``{"max": N}``
    Rules without an expectation entry fall back to their defaults (R2/R3
    always run; R1/R4 are skipped when unparameterized).
    """
    name: str
    hlo_text: str = ""
    jaxpr: Any = None                    # ClosedJaxpr | None
    cost: dict = dataclasses.field(default_factory=dict)
    donated_args: int = 0                # donated leaves at the jit boundary
    nondonated_args: int = 0             # non-donated input leaves
    aliased_args: int | None = None   # parsed from HLO when None
    compile_count: int | None = None  # jit cache size behind the callable
    expect: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def capture(cls, name: str, fn, *args, expect: dict | None = None,
                donated_leaves: int = 0, compile_count: int | None = None,
                **kwargs) -> "CompiledProgram":
        """Lower + compile a jitted callable on abstract (or concrete) args
        and record jaxpr, optimized HLO, and cost analysis."""
        compiled = fn.lower(*args, **kwargs).compile()
        txt = compiled.as_text()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        try:
            jaxpr = fn.trace(*args, **kwargs).jaxpr
        except (AttributeError, TypeError):  # pre-AOT-API jax fallback
            jaxpr = None
        n_inputs = len(jax.tree_util.tree_leaves((args, kwargs)))
        return cls(name=name, hlo_text=txt, jaxpr=jaxpr,
                   cost=dict(cost or {}), donated_args=donated_leaves,
                   nondonated_args=max(0, n_inputs - donated_leaves),
                   aliased_args=alias_count(txt), compile_count=compile_count,
                   expect=dict(expect or {}))


@dataclasses.dataclass
class PallasLaunch:
    """Static launch geometry of one Pallas kernel call (R5).

    ``blocks`` maps a named grid axis to ``(padded_extent, block)`` — the
    divisibility obligation; ``alignments`` maps a label to
    ``(value, multiple)`` — TPU tiling obligations the kernel's own math is
    supposed to guarantee.  ``interpret``/``impl`` record what the dispatch
    seam actually selected."""
    kernel: str
    grid: tuple
    blocks: dict
    alignments: dict = dataclasses.field(default_factory=dict)
    interpret: bool = False
    impl: str = "auto"


@dataclasses.dataclass
class Finding:
    rule: str
    program: str
    message: str

    def __str__(self):
        return f"[{self.rule}] {self.program}: {self.message}"


@dataclasses.dataclass
class AuditReport:
    findings: list
    checked: list                        # (rule, program-name) pairs

    @property
    def ok(self) -> bool:
        return not self.findings

    def raise_if_failed(self) -> None:
        if self.findings:
            raise AssertionError(
                "audit failed:\n" + "\n".join(str(f) for f in self.findings))

    def to_dict(self) -> dict:
        per_rule: dict = {}
        for rule, prog in self.checked:
            per_rule.setdefault(rule, {"checked": [], "findings": []})
            per_rule[rule]["checked"].append(prog)
        for f in self.findings:
            per_rule.setdefault(f.rule, {"checked": [], "findings": []})
            per_rule[f.rule]["findings"].append(
                {"program": f.program, "message": f.message})
        return {"ok": self.ok, "n_checked": len(self.checked),
                "n_findings": len(self.findings), "rules": per_rule}

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)


# --------------------------------------------------------------------------
# R1 — collective placement (the refactored window/ring checkers)
# --------------------------------------------------------------------------
def window_payload_problems(hlo_text: str, expected_bytes: int, *,
                            op: str = "all-reduce",
                            count: int | None = None,
                            by_dtype: dict | None = None,
                            baseline_bytes: int | None = None,
                            delta_bytes: int | None = None,
                            opt_bytes: int | None = None):
    """The window-payload check as a pure function: returns
    ``(collective op records, problems)`` instead of raising, so it can be
    an R1 rule instance AND back the assert-style entry points.  Parameter
    semantics are documented on ``analysis/hlo.verify_window_payload``
    (which delegates here).  Misuse of the parameters themselves still
    raises ValueError.

    ``opt_bytes``: per-worker size of the local optimizer state
    (``coda.opt_state_bytes``).  Preconditioning is strictly local — the
    window collective must NEVER carry it — so when the shipped bytes
    exceed the expectation by exactly this amount the mismatch message
    names the cause instead of leaving a raw byte delta to decode."""
    if (baseline_bytes is None) != (delta_bytes is None):
        raise ValueError("baseline_bytes and delta_bytes go together")
    problems = []
    if baseline_bytes is not None and \
            baseline_bytes + delta_bytes != expected_bytes:
        problems.append(
            f"payload delta mismatch: baseline {baseline_bytes} + delta "
            f"{delta_bytes} != expected {expected_bytes}")
    ops = H.collective_ops(hlo_text)
    stray = [o for o in ops if o["op"] != op]
    if stray:
        problems.append(
            f"expected only {op} ops, found "
            f"{[(o['op'], o['bytes']) for o in stray]}")
    if count is not None:
        if len(ops) != count:
            problems.append(
                f"expected exactly {count} {op} op(s), found "
                f"{[(o['op'], o['bytes']) for o in ops]}")
    elif by_dtype is None:
        seen: dict = {}
        for o in ops:
            for dt in o["by_dtype"]:
                seen[dt] = seen.get(dt, 0) + 1
        dup = {dt: n for dt, n in seen.items() if n > 1}
        if dup or not ops:
            problems.append(
                f"expected one {op} per payload dtype bucket, found "
                f"{[(o['op'], o['by_dtype']) for o in ops]}")
    if by_dtype is not None:
        if sum(by_dtype.values()) != expected_bytes:
            problems.append(
                f"by_dtype buckets sum to {sum(by_dtype.values())}, "
                f"expected_bytes says {expected_bytes}")
        unmatched = list(ops)
        for tag, b in sorted(by_dtype.items()):
            hit = None
            for o in unmatched:
                if o["by_dtype"] == {tag: b}:
                    hit = o          # verbatim wire dtype
                    break
                if tag in ("bf16", "f16") and o["by_dtype"] == {"f32": 2 * b}:
                    hit = o          # float-normalized to f32, same elements
                    break
            if hit is None:
                problems.append(
                    f"no {op} carries the {tag} bucket of {b} bytes "
                    f"(ops: {[(o['op'], o['by_dtype']) for o in ops]})")
                continue
            unmatched.remove(hit)
        if unmatched:
            msg = (f"stray {op} beyond the accounted dtype buckets: "
                   f"{[(o['op'], o['by_dtype']) for o in unmatched]}")
            stray_b = sum(o["bytes"] for o in unmatched)
            if opt_bytes and stray_b == opt_bytes:
                msg += (f" — the stray bytes equal the per-worker optimizer "
                        f"state ({opt_bytes} B): optimizer state leaked "
                        f"onto the wire")
            problems.append(msg)
    else:
        total = sum(o["bytes"] for o in ops)
        if total != expected_bytes:
            msg = (f"window payload mismatch: HLO ships {total} bytes, "
                   f"accounting says {expected_bytes} "
                   f"({[(o['op'], o['bytes']) for o in ops]})")
            if opt_bytes and total == expected_bytes + opt_bytes:
                msg += (f" — the excess equals the per-worker optimizer "
                        f"state ({opt_bytes} B): optimizer state leaked "
                        f"onto the wire")
            problems.append(msg)
    return ops, problems


def overlapped_window_problems(hlo_text: str, *, n_hops: int,
                               n_chains: int | None = None,
                               require_compute_between: bool = True):
    """The overlapped-ring schedule check as a pure function: returns
    ``(permute op records, problems)``.  Semantics documented on
    ``analysis/hlo.verify_overlapped_window`` (which delegates here)."""
    problems = []
    ops = H.collective_ops(hlo_text)
    stray = [o for o in ops if o["op"] != "collective-permute"]
    if stray:
        problems.append(
            "overlapped window must not contain blocking collectives, found "
            f"{[(o['op'], o['bytes']) for o in stray]}")
    if len(ops) != n_hops:
        problems.append(
            f"expected {n_hops} collective-permute hops, found {len(ops)}")
    if n_chains is not None:
        got = H.permute_chain_components(hlo_text)
        if got != n_chains:
            problems.append(
                f"expected {n_chains} independent permute chains, found "
                f"{got} — the chunked ring degenerated (de-chunked or "
                "cross-chunk serialized)")
    if require_compute_between and ops and not stray:
        dotted = H._dot_bearing_computations(hlo_text)
        lines = hlo_text.splitlines()
        hop_idx = [i for i, ln in enumerate(lines) if H._OP_RE.search(ln)]
        found = False
        for ln in lines[hop_idx[0] + 1:hop_idx[-1]]:
            if H._DOT_RE.search(ln):          # an unfused dot right there
                found = True
                break
            if any(c.lstrip("%") in dotted
                   for c in H._CALLEE_RE.findall(ln)):
                found = True
                break
        if not found:
            problems.append(
                "no dot-bearing compute scheduled between the first and last "
                "ring hop — the two windows were not fused around the "
                "averaging")
    return ops, problems


def gather_pair_problems(hlo_text: str, *, payload_bytes: int,
                         n_workers: int):
    """The int8 compressed-averaging wire check: every collective is an
    all-gather, the wire carries only the s8 payload plus fp32 scales, and
    the gathered bytes per worker equal the documented compressed payload
    (``coda.window_payload_bytes(state, "int8")``)."""
    problems = []
    ops = H.collective_ops(hlo_text)
    stray = [o for o in ops if o["op"] != "all-gather"]
    if stray:
        problems.append(
            "int8 averaging must ship all-gather only, found "
            f"{[(o['op'], o['bytes']) for o in stray]}")
    by_dtype: dict = {}
    for o in ops:
        for dt, b in o["by_dtype"].items():
            by_dtype[dt] = by_dtype.get(dt, 0) + b
    extra = set(by_dtype) - {"s8", "f32"}
    if extra:
        problems.append(
            f"int8 wire must be s8 payload + f32 scales, found dtypes "
            f"{sorted(by_dtype)}")
    if ops and not by_dtype.get("s8"):
        problems.append(
            "int8 wire ships no s8 bytes — the payload left the worker "
            f"uncompressed (dtypes: {sorted(by_dtype)})")
    total = sum(by_dtype.values())
    if total != n_workers * payload_bytes:
        problems.append(
            f"gathered bytes {total} != K({n_workers}) × compressed payload "
            f"({payload_bytes})")
    return ops, problems


def assert_window_payload(hlo_text: str, expected_bytes: int, **kw):
    """Raise AssertionError on the first window-payload problem; return the
    collective op records on success.  The rule-engine entry point behind
    ``analysis/hlo.verify_window_payload`` — same raise/return contract."""
    ops, problems = window_payload_problems(hlo_text, expected_bytes, **kw)
    if problems:
        raise AssertionError(problems[0])
    return ops


def assert_overlapped_window(hlo_text: str, *, n_hops: int,
                             n_chains: int | None = None,
                             require_compute_between: bool = True):
    """Raise AssertionError on the first ring-schedule problem; return the
    permute op records on success (behind
    ``analysis/hlo.verify_overlapped_window``)."""
    ops, problems = overlapped_window_problems(
        hlo_text, n_hops=n_hops, n_chains=n_chains,
        require_compute_between=require_compute_between)
    if problems:
        raise AssertionError(problems[0])
    return ops


def rule_collective_placement(prog: CompiledProgram):
    """R1: collectives appear exactly where the algorithm says they do."""
    spec = prog.expect.get("collectives")
    if spec is None:
        return []
    kind = spec.get("kind")
    if kind == "none":
        ops = H.collective_ops(prog.hlo_text)
        if ops:
            return [Finding("R1", prog.name,
                            "must be collective-free, found "
                            f"{[(o['op'], o['bytes']) for o in ops]}")]
        return []
    if kind == "window":
        keys = ("op", "count", "by_dtype", "baseline_bytes", "delta_bytes",
                "opt_bytes")
        _, problems = window_payload_problems(
            prog.hlo_text, spec["expected_bytes"],
            **{k: spec[k] for k in keys if k in spec})
    elif kind == "ring":
        _, problems = overlapped_window_problems(
            prog.hlo_text, n_hops=spec["n_hops"],
            n_chains=spec.get("n_chains"),
            require_compute_between=spec.get("require_compute_between", True))
    elif kind == "gather_pair":
        _, problems = gather_pair_problems(
            prog.hlo_text, payload_bytes=spec["payload_bytes"],
            n_workers=spec["n_workers"])
    else:
        raise ValueError(f"unknown R1 expectation kind {kind!r}")
    return [Finding("R1", prog.name, p) for p in problems]


# --------------------------------------------------------------------------
# R2 — donation audit
# --------------------------------------------------------------------------
# one entry per aliased output buffer: "{1}: (0, {3}, may-alias)"; a single
# non-tuple output indexes as the empty shape path "{}: (0, {}, may-alias)"
_ALIAS_ENTRY_RE = re.compile(r"\{[\d,\s]*\}:\s*\(")


def alias_count(hlo_text: str) -> int:
    """Number of input/output alias entries in the optimized module header —
    one per parameter buffer XLA accepted for reuse.  A donated-but-dropped
    buffer has no entry."""
    for line in hlo_text.splitlines():
        if "HloModule" not in line:
            continue
        m = re.search(r"input_output_alias=\{(.*)$", line)
        if not m:
            return 0
        return len(_ALIAS_ENTRY_RE.findall(m.group(1)))
    return 0


_PARAM_RE = re.compile(r"=\s*[^=]*\bparameter\(\d+\)")


def entry_param_count(hlo_text: str) -> int:
    """Number of parameters the optimized ENTRY computation still has.
    XLA deletes unused inputs outright (e.g. a stage program's ``ref_*``
    anchors, whose outputs dedup onto the freshly averaged params), so
    ``donated − (inputs − entry params)`` is the number of donations that
    can possibly alias."""
    lines = hlo_text.splitlines()
    start = next((i for i, ln in enumerate(lines)
                  if ln.startswith("ENTRY ")), None)
    if start is None:
        return 0
    n = 0
    for ln in lines[start + 1:]:
        if ln.strip() == "}":
            break
        if _PARAM_RE.search(ln):
            n += 1
    return n


def rule_donation(prog: CompiledProgram):
    """R2: every donated buffer that SURVIVES as an entry parameter is
    actually aliased in the compiled output.  (A donated input XLA deleted
    as unused never materializes, so there is nothing to alias — but a
    live parameter that was donated and not aliased means XLA rejected the
    reuse, silently doubling peak memory for that buffer: hard failure.)"""
    if prog.donated_args == 0:
        return []
    aliased = prog.aliased_args
    if aliased is None:
        aliased = alias_count(prog.hlo_text)
    n_params = entry_param_count(prog.hlo_text)
    # surviving donated params, assuming dropped inputs are donated ones
    # first (conservative: a dropped NON-donated input only lowers the bound)
    expected = max(0, n_params - prog.nondonated_args)
    if aliased < expected:
        return [Finding(
            "R2", prog.name,
            f"{prog.donated_args} buffers donated, {expected} survive as "
            f"entry parameters, but only {aliased} aliased in the compiled "
            "output — a dropped donation doubles peak memory for that "
            "buffer")]
    return []


# --------------------------------------------------------------------------
# R3 — host-sync / dtype lint on jaxprs
# --------------------------------------------------------------------------
# primitives that round-trip through the host (sync points) or move buffers
# between devices mid-program — none belong in a jitted hot path
_HOST_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed", "host_callback_call",
})
_TRANSFER_PRIMS = frozenset({"device_put", "copy_to_host_async"})
# accumulation-bearing primitives: sub-fp32 operands must accumulate wider
_ACCUM_PRIMS = frozenset({"dot_general", "reduce_sum", "reduce_prod"})


def iter_eqns(jaxpr):
    """Yield every equation in a (Closed)Jaxpr, recursing through nested
    jaxprs in eqn params (scan/while/cond bodies, pjit/shard_map callees) —
    the hot-path ops hide there, not at the top level."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else [v]):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    yield from iter_eqns(sub)


def _is_float(dt) -> bool:
    return jnp.issubdtype(dt, jnp.floating)


def jaxpr_problems(jaxpr, *, allow_f64: bool = False) -> list:
    """The R3 lint over one program's jaxpr: f64 creep, host
    callbacks/transfers, sub-fp32 accumulation."""
    problems = []
    f64_hits = set()
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name in _HOST_CALLBACK_PRIMS:
            problems.append(
                f"host callback `{name}` inside a jitted hot path (implicit "
                "host sync every step)")
        elif name in _TRANSFER_PRIMS:
            problems.append(
                f"device transfer `{name}` inside a jitted hot path")
        if not allow_f64:
            for var in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(var, "aval", None)
                dt = getattr(aval, "dtype", None)
                if dt is not None and jnp.dtype(dt) == jnp.float64:
                    f64_hits.add(name)
        if name in _ACCUM_PRIMS:
            in_dts = [jnp.dtype(v.aval.dtype) for v in eqn.invars
                      if hasattr(getattr(v, "aval", None), "dtype")]
            narrow = [dt for dt in in_dts
                      if _is_float(dt) and dt.itemsize < 4]
            if not narrow:
                continue
            acc = eqn.params.get("preferred_element_type")
            if acc is None and eqn.outvars:
                acc = eqn.outvars[0].aval.dtype
            if acc is not None and _is_float(jnp.dtype(acc)) \
                    and jnp.dtype(acc).itemsize < 4:
                problems.append(
                    f"`{name}` over {narrow[0].name} operands accumulates in "
                    f"{jnp.dtype(acc).name} — reductions must accumulate in "
                    "≥ fp32")
    for name in sorted(f64_hits):
        problems.append(
            f"f64 value flows through `{name}` — f64 creep in a hot path "
            "(x64 mode doubles every downstream buffer)")
    return problems


def rule_host_sync(prog: CompiledProgram):
    """R3: jaxpr lint (skipped when the program carries no jaxpr)."""
    if prog.jaxpr is None:
        return []
    allow = prog.expect.get("allow_f64", False)
    return [Finding("R3", prog.name, p)
            for p in jaxpr_problems(prog.jaxpr, allow_f64=allow)]


# --------------------------------------------------------------------------
# R4 — recompile budget
# --------------------------------------------------------------------------
def rule_recompile_budget(prog: CompiledProgram):
    """R4: the callable behind this program compiled exactly/at-most the
    documented number of executables."""
    spec = prog.expect.get("compiles")
    if spec is None or prog.compile_count is None:
        return []
    if "exact" in spec and prog.compile_count != spec["exact"]:
        return [Finding(
            "R4", prog.name,
            f"compiled {prog.compile_count} programs, budget says exactly "
            f"{spec['exact']} — a shape/dtype leak is re-tracing the hot "
            "path")]
    if "max" in spec and prog.compile_count > spec["max"]:
        return [Finding(
            "R4", prog.name,
            f"compiled {prog.compile_count} programs, budget allows at most "
            f"{spec['max']}")]
    return []


# --------------------------------------------------------------------------
# R5 — Pallas static checks
# --------------------------------------------------------------------------
def launch_problems(launch: PallasLaunch) -> list:
    problems = []
    if not launch.grid or any(g < 1 for g in launch.grid):
        problems.append(f"degenerate grid {launch.grid}")
    for axis, (extent, block) in launch.blocks.items():
        if block < 1:
            problems.append(f"axis {axis}: non-positive block {block}")
            continue
        if extent % block != 0:
            problems.append(
                f"axis {axis}: padded extent {extent} not divisible by "
                f"block {block} — partial tiles would read out of bounds")
        if block > extent:
            problems.append(
                f"axis {axis}: block {block} exceeds padded extent {extent}")
    for label, (value, multiple) in launch.alignments.items():
        if value % multiple != 0:
            problems.append(
                f"alignment {label}: {value} is not a multiple of {multiple}")
    if launch.interpret and launch.impl != "pallas":
        problems.append(
            f"interpret-mode selected by impl={launch.impl!r} — only the "
            "explicit \"pallas\" override may interpret off-TPU")
    return problems


def rule_pallas_static(launch: PallasLaunch):
    return [Finding("R5", launch.kernel, p) for p in launch_problems(launch)]


def dispatch_problems() -> list:
    """The dispatch-seam half of R5 on the CURRENT backend: "auto" and
    "ref" must never select interpret mode; "pallas" interprets exactly
    when off-TPU."""
    from repro.kernels import ops as kops
    problems = []
    on_tpu = jax.default_backend() == "tpu"
    for impl in ("auto", "ref"):
        _, interpret = kops.dispatch(impl)
        if interpret:
            problems.append(
                f'dispatch("{impl}") selected interpret mode on the '
                f"{jax.default_backend()} backend")
    if kops.dispatch("pallas")[1] != (not on_tpu):
        problems.append(
            'dispatch("pallas") interpret flag disagrees with the backend')
    return problems


# --------------------------------------------------------------------------
# rule engine
# --------------------------------------------------------------------------
PROGRAM_RULES: dict = {
    "R1": rule_collective_placement,
    "R2": rule_donation,
    "R3": rule_host_sync,
    "R4": rule_recompile_budget,
}


def run_rules(programs, launches=(), *, rules=None,
              check_dispatch: bool = True) -> AuditReport:
    """Run the rule set over captured programs + kernel launches and return
    an :class:`AuditReport`.  ``rules`` narrows to a subset of
    {"R1".."R5"} (default: all)."""
    selected = set(rules) if rules is not None else {"R1", "R2", "R3", "R4",
                                                     "R5"}
    findings, checked = [], []
    for prog in programs:
        for rid, rule in PROGRAM_RULES.items():
            if rid not in selected:
                continue
            findings.extend(rule(prog))
            checked.append((rid, prog.name))
    if "R5" in selected:
        for launch in launches:
            findings.extend(rule_pallas_static(launch))
            checked.append(("R5", launch.kernel))
        if check_dispatch:
            findings.extend(Finding("R5", "kernels.ops.dispatch", p)
                            for p in dispatch_problems())
            checked.append(("R5", "kernels.ops.dispatch"))
    return AuditReport(findings=findings, checked=checked)


# --------------------------------------------------------------------------
# program registry: training executors
# --------------------------------------------------------------------------
def _payload_by_dtype_or_none(state, mult_aware=True, *, masked=False):
    from repro.core import coda
    by_dtype = coda.window_payload_by_dtype(state, masked=masked)
    return by_dtype if len(by_dtype) > 1 else None


def _fault_vectors(ccfg, K: int, *, abstract: bool):
    """The traced fault-vector argument the executors take when
    ``ccfg.faults_enabled`` (full participation — the R1/R4 contracts are
    shape properties, the schedule is data)."""
    if not ccfg.faults_enabled:
        return None
    if abstract:
        v = jax.ShapeDtypeStruct((K,), jnp.float32)
        return {"weights": v, "resync": v}
    return {"weights": jnp.ones((K,), jnp.float32),
            "resync": jnp.ones((K,), jnp.float32)}


def _abstract(tree):
    return jax.eval_shape(lambda t: t, tree)


def _mlp_window(mcfg, K: int, I: int, B: int):
    """Abstract window/alpha batches for the feature-vector configs the
    audit matrix trains (mirrors the tier-1 test batches)."""
    nf = mcfg.n_features
    wb = {"features": jax.ShapeDtypeStruct((I, K, B, nf), jnp.float32),
          "labels": jax.ShapeDtypeStruct((I, K, B), jnp.float32)}
    ab = {"features": jax.ShapeDtypeStruct((K, B, nf), jnp.float32),
          "labels": jax.ShapeDtypeStruct((K, B), jnp.float32)}
    return wb, ab


def _concrete_window(key, mcfg, K: int, I: int, B: int):
    nf = mcfg.n_features
    ky, kx = jax.random.split(key)
    y = (jax.random.uniform(ky, (I, K, B)) < 0.7).astype(jnp.float32)
    x = jax.random.normal(kx, (I, K, B, nf)) + 0.3 * (y[..., None] * 2 - 1)
    return {"features": x, "labels": y}


def capture_vmap_programs(mcfg, ccfg, *, I: int = 2, B: int = 8,
                          window_lens=(1, 2), seed: int = 0, tag: str = "vmap"):
    """Registry capture for the ``core/coda.py`` oracle executor.

    Lowers the window + stage programs (R1 "collective-free": the oracle's
    worker axis is a vmap axis, nothing may cross a wire), audits donation
    and the jaxpr, and drives the executor over ``window_lens`` to pin the
    R4 budget: one compile per distinct window length, none for repeats.
    """
    from repro.core import coda
    exe = coda.make_executor(mcfg, ccfg, "vmap", donate=True)
    K = ccfg.n_workers
    key = jax.random.PRNGKey(seed)
    st0 = coda.init_state(key, mcfg, ccfg)
    n_state_leaves = len(jax.tree_util.tree_leaves(st0))
    sts = _abstract(st0)
    wb, ab = _mlp_window(mcfg, K, I, B)
    eta = jax.ShapeDtypeStruct((), jnp.float32)
    fls = _fault_vectors(ccfg, K, abstract=True)
    fli = _fault_vectors(ccfg, K, abstract=False)
    wargs = (sts, wb, eta) if fls is None else (sts, wb, eta, fls)

    # R4: drive the executor eagerly — repeats must not re-trace, distinct
    # window lengths compile once each.  Under fault injection the fault
    # vectors are a fixed-shape traced arg, so the budget is unchanged.
    st = exe.place(st0)
    for wl in tuple(window_lens) + (window_lens[0],):
        wbi = _concrete_window(key, mcfg, K, wl, B)
        st, _ = exe.window_step(st, wbi, 0.1, **(
            {} if fli is None else {"faults": fli}))
    abi = jax.tree_util.tree_map(
        lambda l: l[0], _concrete_window(key, mcfg, K, 1, B))
    st = exe.stage_end(st, abi)

    # lower()/compile() below go through the AOT path and do not add cache
    # entries, so the budget is purely what the drive dispatched: one
    # executable per distinct window length, one stage program
    programs = [
        CompiledProgram.capture(
            f"{tag}/window", exe._wstep, *wargs,
            expect={"collectives": {"kind": "none"},
                    "compiles": {"exact": len(set(window_lens))}},
            donated_leaves=n_state_leaves,
            compile_count=exe._wstep._cache_size()),
        CompiledProgram.capture(
            f"{tag}/stage", exe._send, sts, ab,
            expect={"collectives": {"kind": "none"},
                    "compiles": {"exact": 1}},
            donated_leaves=n_state_leaves,
            compile_count=exe._send._cache_size()),
    ]
    return programs


def capture_sharded_programs(mcfg, ccfg, mesh, *, policy: str = "replica",
                             I: int = 2, B: int = 8, window_lens=(1, 2),
                             seed: int = 0, tag: str = "sharded"):
    """Registry capture for ``core/coda_sharded.py``: the local-step body
    (communicate=False — R1 collective-free), the window (ONE bucketed
    all-reduce of the documented payload / the int8 all-gather pair), the
    fused overlapped pair (the asserted ring schedule), and the stage
    program (one all-reduce of the stage-dual scalars)."""
    from repro.core import bucketing, coda
    exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                             policy=policy, donate=True)
    K = ccfg.n_workers
    key = jax.random.PRNGKey(seed)
    st0 = coda.init_state(key, mcfg, ccfg)
    n_state_leaves = len(jax.tree_util.tree_leaves(st0))
    sts = _abstract(st0)
    wb, ab = _mlp_window(mcfg, K, I, B)
    eta = jax.ShapeDtypeStruct((), jnp.float32)
    fls = _fault_vectors(ccfg, K, abstract=True)
    fli = _fault_vectors(ccfg, K, abstract=False)
    masked = fls is not None
    wargs = (sts, wb, eta) if fls is None else (sts, wb, eta, fls)
    wired = bool(exe.worker_axes)        # K=1 degenerate partitions: no wire
    compress = ccfg.avg_compress or None

    if not wired:
        window_expect = {"kind": "none"}
    elif compress == "int8":
        window_expect = {
            "kind": "gather_pair",
            "payload_bytes": coda.window_payload_bytes(st0, "int8",
                                                       masked=masked),
            "n_workers": K}
    else:
        window_expect = {
            "kind": "window",
            "expected_bytes": coda.window_payload_bytes(st0, masked=masked)}
        by_dtype = _payload_by_dtype_or_none(st0, masked=masked)
        if by_dtype:
            window_expect["by_dtype"] = by_dtype
        ob = coda.opt_state_bytes(st0)
        if ob:                           # diagnose an exact-size excess as
            window_expect["opt_bytes"] = ob   # an optimizer-state wire leak

    stage_bytes = coda.stage_payload_bytes(ccfg)
    if wired and stage_bytes:
        stage_expect = {"kind": "window", "expected_bytes": stage_bytes}
    else:
        stage_expect = {"kind": "none"}

    programs = [
        CompiledProgram.capture(
            f"{tag}/local_steps", exe.window_fn(sts, wb, communicate=False),
            *wargs,
            expect={"collectives": {"kind": "none"}},
            donated_leaves=n_state_leaves),
        CompiledProgram.capture(
            f"{tag}/window", exe.window_fn(sts, wb), *wargs,
            expect={"collectives": window_expect},
            donated_leaves=n_state_leaves),
        CompiledProgram.capture(
            f"{tag}/stage", exe.stage_fn(sts, ab), sts, ab,
            expect={"collectives": stage_expect},
            donated_leaves=n_state_leaves),
    ]

    if exe.overlap_pairs:
        wb2 = {"features": jax.ShapeDtypeStruct((2, I, K, B, mcfg.n_features),
                                                jnp.float32),
               "labels": jax.ShapeDtypeStruct((2, I, K, B), jnp.float32)}
        mats, _, _ = bucketing._state_mats(st0)
        if "cv_params" in st0:
            mats = mats * 2              # variates ride the same buckets
        if masked:                       # weight lane(s) ride the f32 bucket
            n_lanes = 2 if "cv_params" in st0 else 1
            mats = mats + [jnp.zeros((K, n_lanes), jnp.float32)]
        ring = exe._ring_spec()
        sizes = bucketing.bucket_sizes(mats)
        n_hops = 2 * bucketing.ring_hop_count(sizes, ring)      # 2 rings/pair
        n_chains = 2 * bucketing.ring_chain_count(sizes, ring)
        if masked:
            v2 = jax.ShapeDtypeStruct((2, K), jnp.float32)
            pargs = (sts, wb2, eta, {"weights": v2, "resync": v2})
        else:
            pargs = (sts, wb2, eta)
        # chain independence needs the local steps to lower as a while loop
        # (I >= 2); an I=1 window inlines and legitimately merges the chains
        programs.append(CompiledProgram.capture(
            f"{tag}/pair", exe.window_pair_fn(sts, wb2), *pargs,
            expect={"collectives": {
                "kind": "ring", "n_hops": n_hops,
                "n_chains": n_chains if I > 1 else None}},
            donated_leaves=n_state_leaves))

    # R4: drive eagerly over repeated + distinct window lengths; the cache
    # behind each (tag, treedef, ndim) entry must hold one executable per
    # distinct shape set and nothing more.  One warmup call first: the
    # explicitly place()d state keys differently from the jit's own output
    # sharding, so the very first dispatch compiles a startup-only variant —
    # the budget pins the steady state after it.
    fkw = {} if fli is None else {"faults": fli}
    st = exe.place(st0)
    st, _ = exe.window_step(
        st, _concrete_window(key, mcfg, K, window_lens[0], B), 0.1, **fkw)
    fn = exe.window_fn(sts, wb)          # same cache entry the drive uses
    fn.clear_cache()
    for wl in tuple(window_lens) + (window_lens[0],):
        wbi = _concrete_window(key, mcfg, K, wl, B)
        st, _ = exe.window_step(st, wbi, 0.1, **fkw)
    n_expected = len(set(window_lens))
    programs.append(CompiledProgram(
        name=f"{tag}/window_cache",
        compile_count=fn._cache_size(),
        expect={"compiles": {"exact": n_expected}}))
    return programs


def capture_training_programs(mcfg, ccfg, *, executor: str = "vmap",
                              mesh=None, policy: str = "replica",
                              I: int = 2, B: int = 8, window_lens=(1, 2),
                              seed: int = 0, tag: str | None = None):
    """Dispatch to the per-executor capture (the registry's training half)."""
    if executor == "vmap":
        return capture_vmap_programs(mcfg, ccfg, I=I, B=B,
                                     window_lens=window_lens, seed=seed,
                                     tag=tag or "vmap")
    if executor == "shard_map":
        if mesh is None:
            raise ValueError("shard_map capture needs a mesh")
        return capture_sharded_programs(mcfg, ccfg, mesh, policy=policy,
                                        I=I, B=B, window_lens=window_lens,
                                        seed=seed, tag=tag or "sharded")
    raise ValueError(f"unknown executor {executor!r}")


# --------------------------------------------------------------------------
# program registry: serving
# --------------------------------------------------------------------------
def capture_serving_programs(cfg=None, *, slots: int = 2, max_len: int = 32,
                             prefill_chunk: int = 4, use_window: bool = True,
                             impl: str = "auto", tag: str = "serve"):
    """Registry capture for ``serving/engine.py``: the two chunk programs
    (C = prefill_chunk for batched chunked prefill, C = 1 for decode-only
    ticks).  Both must be collective-free and host-sync-free; the R4 budget
    is the engine's headline claim — a mixed prefill/decode workload
    compiles EXACTLY those two executables and nothing else."""
    from repro.models import init_params
    from repro.serving import engine as E

    if cfg is None:
        from repro.configs import get_smoke_config
        cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)

    E._chunk_step.clear_cache()
    eng = E.ServingEngine(cfg, params, slots=slots, max_len=max_len,
                          use_window=use_window, impl=impl,
                          prefill_chunk=prefill_chunk)
    # mixed workload: prompts longer than one chunk force prefill ticks AND
    # decode-only ticks (C collapses to 1 once every prompt is consumed)
    for uid in range(slots + 1):
        eng.add_request(E.Request(uid=uid,
                                  prompt=[2 + uid, 3, 4, 5, 6, 7],
                                  max_new_tokens=4))
    eng.run()
    cache_size = E._chunk_step._cache_size()

    cache_s = _abstract(eng.cache)
    pos = jax.ShapeDtypeStruct((slots,), jnp.int32)
    nst = jax.ShapeDtypeStruct((slots,), jnp.int32)
    programs = []
    for C, name in ((prefill_chunk, "prefill_chunk"), (1, "decode_step")):
        toks = jax.ShapeDtypeStruct((slots, C), jnp.int32)
        programs.append(CompiledProgram.capture(
            f"{tag}/{name}", E._chunk_step, cfg, params, cache_s, toks, pos,
            nst, use_window=use_window, impl=impl,
            expect={"collectives": {"kind": "none"}}))
    programs.append(CompiledProgram(
        name=f"{tag}/chunk_step_cache", compile_count=cache_size,
        expect={"compiles": {"exact": 2}}))
    return programs


# --------------------------------------------------------------------------
# program registry: the kernels seam
# --------------------------------------------------------------------------
def capture_kernel_launches(*, impl: str = "auto", shapes=None):
    """Static launch records for every Pallas kernel, computed from the
    ``launch_geometry`` each kernel module launches with (single source of
    truth — the audit cannot drift from the kernel).  ``shapes`` overrides
    the representative problem sizes."""
    from repro.kernels import ops as kops
    from repro.kernels import auc_loss as AK
    from repro.kernels import flash_attention as FK
    from repro.kernels import moe_dispatch as MK
    from repro.kernels import opt_update as OK
    from repro.kernels import prox_update as PK

    s = {"moe": (64, 32, 4, 64), "auc": (300,), "prox": (1000,),
         "opt": (1000,), "flash": (1, 256, 4, 2, 256, 64)}
    s.update(shapes or {})
    _, interpret = kops.dispatch(impl)
    launches = []

    N, K, E, F = s["moe"]
    g = MK.launch_geometry(N, K, E, F)
    launches.append(PallasLaunch(
        kernel="moe_dispatch", grid=g["grid"],
        blocks={"rows": (g["Np"], g["bm"]), "ff": (g["Fp"], g["bn"])},
        alignments={"bm%8": (g["bm"], 8), "bn%128": (g["bn"], 128),
                    "Kp%128": (g["Kp"], 128)},
        interpret=interpret, impl=impl))

    (T,) = s["auc"]
    g = AK.launch_geometry(T)
    launches.append(PallasLaunch(
        kernel="auc_loss", grid=g["grid"], blocks={"t": (g["Tp"], g["bt"])},
        interpret=interpret, impl=impl))

    (N,) = s["prox"]
    g = PK.launch_geometry(N)
    launches.append(PallasLaunch(
        kernel="prox_update", grid=g["grid"],
        blocks={"n": (g["Np"], g["bt"])}, interpret=interpret, impl=impl))

    (N,) = s["opt"]
    g = OK.launch_geometry(N)
    for mode in ("momentum", "precond"):
        launches.append(PallasLaunch(
            kernel=f"opt_update[{mode}]", grid=g["grid"],
            blocks={"n": (g["Np"], g["bt"])}, interpret=interpret, impl=impl))

    B, S, nH, KV, Skv, hd = s["flash"]
    g = FK.launch_geometry(B, S, nH, KV, Skv, hd)
    launches.append(PallasLaunch(
        kernel="flash_attention", grid=g["grid"],
        blocks={"q": (S, g["bq"]), "kv": (Skv, g["bk"])},
        interpret=interpret, impl=impl))
    return launches
