"""Configuration dataclasses for every architecture the framework supports.

A ``ModelConfig`` fully determines parameter shapes and the forward pass of
the scoring network ``h(w; x)`` used by CoDA, as well as the autoregressive
``serve_step`` used by the decode input shapes.  One module per assigned
architecture lives next to this file; each exports ``CONFIG`` (the exact
pool numbers) and ``smoke_config()`` (a reduced same-family variant for CPU
tests: <=2 layers, d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration.

    ``dispatch`` selects the EVAL/DECODE dispatch implementation (training
    always uses capacity-factor dispatch — dropping over-capacity tokens is
    the load-shedding regularizer):
      * "sorted"   — dropless sort-based dispatch: [T·k, d] buffer + ragged
                     grouped GEMM over expert segments (models/moe.py).
      * "capacity" — the padded scatter dispatch at the static dropless
                     bound C = T: an [E, T, d] buffer, ~E/top_k-fold
                     oversized in expectation (kept for A/B and as the
                     oracle the sorted path is tested against).
    """

    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # Arctic keeps a dense (always-on) residual MLP next to the experts.
    dense_residual: bool = False
    dense_d_ff: int = 0
    dispatch: str = "sorted"

    def __post_init__(self):
        if self.dispatch not in ("sorted", "capacity"):
            raise ValueError(
                f"unknown moe dispatch {self.dispatch!r} (want sorted | capacity)")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    ``family`` is one of: dense | moe | vlm | audio | hybrid | ssm.
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""  # citation for the config numbers

    # --- attention details -------------------------------------------------
    head_dim: int = 0  # 0 => d_model // n_heads
    rope: str = "1d"  # "1d" | "2d-partial" (ChatGLM) | "partial" | "none"
    rope_fraction: float = 1.0  # fraction of head_dim that is rotated
    rope_base: float = 10000.0
    qkv_bias: bool = False
    norm: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    act: str = "swiglu"  # "swiglu" | "gelu"
    # Sliding-window attention.  ``window`` is the size used when a layer is
    # a window layer; ``window_layers`` says which layers use it ("none",
    # "all", "all_but_global").  Dense archs get window="optional": full
    # attention by default, window only for the long_500k shape.
    window: int = 4096
    window_mode: str = "none"  # "none" | "all_but_global" | "optional"
    global_attn_every: int = 0  # hybrid: every Nth layer uses global attn

    # --- mixture of experts -------------------------------------------------
    moe: MoEConfig | None = None

    # --- state-space / hybrid ----------------------------------------------
    ssm_state: int = 0  # N for mamba-style SSM (hymba)
    ssm_expand: int = 2
    ssm_conv: int = 4
    slstm_every: int = 0  # xLSTM: every Nth block is an sLSTM block

    # --- encoder-decoder (audio) ---------------------------------------------
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    decoder_fraction: int = 4  # decoder seq = seq_len // decoder_fraction

    # --- multimodal stubs -----------------------------------------------------
    n_patches: int = 0  # VLM: number of stubbed vision-patch embeddings

    # --- misc -----------------------------------------------------------------
    tie_embeddings: bool = False
    n_features: int = 0  # mlp family: flat input feature dim

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.family in ("dense", "moe", "vlm", "audio", "hybrid", "ssm",
                               "cnn", "mlp")

    # -- derived quantities used by the roofline -----------------------------
    def param_count(self) -> int:
        """Total parameter count N (per worker replica)."""
        from repro.models import model as _model

        return _model.count_params(self)

    def active_param_count(self) -> int:
        """Active (per-token) parameter count: MoE counts only top-k experts."""
        from repro.models import model as _model

        return _model.count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One of the four assigned global input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


def mlp_config(n_features: int = 64, d: int = 128, n_layers: int = 2) -> ModelConfig:
    """Tiny MLP scorer for fast CPU convergence experiments (the paper's
    trends — linear speedup in K, communication skipping — are model
    agnostic; ResNet50 is available for the faithful variant)."""
    return ModelConfig(name="mlp", family="mlp", n_layers=n_layers, d_model=d,
                       n_heads=1, n_kv_heads=1, d_ff=d, vocab_size=0,
                       rope="none", n_features=n_features)


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def input_specs(
    cfg: ModelConfig,
    shape: ShapeSpec,
    *,
    n_workers: int = 1,
    window_steps: int = 1,
    dtype=jnp.bfloat16,
) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a given shape.

    For training shapes this is the CoDA *window* batch
    ``[window_steps, n_workers, per_worker_batch, ...]``; for decode shapes it
    is the serving request batch (the KV cache itself is produced by
    ``serving.cache_specs``).  No device memory is allocated.
    """
    S = shape.seq_len
    B = shape.global_batch
    if shape.kind in ("train", "prefill"):
        assert B % n_workers == 0, (cfg.name, shape.name, n_workers)
        bw = B // n_workers
        lead: tuple[int, ...] = (window_steps, n_workers, bw)
        specs = {}
        if cfg.family == "vlm":
            n_txt = S - cfg.n_patches
            specs["patches"] = jax.ShapeDtypeStruct(lead + (cfg.n_patches, cfg.d_model), dtype)
            specs["tokens"] = jax.ShapeDtypeStruct(lead + (n_txt,), jnp.int32)
        elif cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(lead + (S, cfg.d_model), dtype)
            specs["tokens"] = jax.ShapeDtypeStruct(lead + (S // cfg.decoder_fraction,), jnp.int32)
        elif cfg.family == "cnn":
            specs["images"] = jax.ShapeDtypeStruct(lead + (S, 3), dtype)  # flattened pixels
        elif cfg.family == "mlp":
            specs["features"] = jax.ShapeDtypeStruct(lead + (cfg.n_features,), dtype)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct(lead + (S,), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct(lead, jnp.float32)
        return specs
    # decode: one new token against a cache of length S
    specs = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
             "positions": jax.ShapeDtypeStruct((B,), jnp.int32)}
    return specs
