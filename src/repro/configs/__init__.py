"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config``."""
from __future__ import annotations

from repro.configs import (
    arctic_480b,
    chatglm3_6b,
    dbrx_132b,
    hymba_1_5b,
    internvl2_2b,
    phi3_medium_14b,
    qwen2_5_14b,
    resnet50,
    seamless_m4t_medium,
    stablelm_1_6b,
    xlstm_350m,
)
from repro.configs.base import SHAPES, ModelConfig, MoEConfig, ShapeSpec, input_specs

_MODULES = {
    "chatglm3-6b": chatglm3_6b,
    "arctic-480b": arctic_480b,
    "dbrx-132b": dbrx_132b,
    "internvl2-2b": internvl2_2b,
    "qwen2.5-14b": qwen2_5_14b,
    "stablelm-1.6b": stablelm_1_6b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "hymba-1.5b": hymba_1_5b,
    "phi3-medium-14b": phi3_medium_14b,
    "xlstm-350m": xlstm_350m,
    "resnet50": resnet50,
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "resnet50")
ALL_ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke_config()


__all__ = [
    "ASSIGNED_ARCHS",
    "ALL_ARCHS",
    "SHAPES",
    "ModelConfig",
    "MoEConfig",
    "ShapeSpec",
    "get_config",
    "get_smoke_config",
    "input_specs",
]
