"""seamless-m4t-medium [audio] — encoder-decoder, multimodal. [arXiv:2308.11596]

The mel-spectrogram + conv feature extractor is stubbed per the assignment
carve-out: ``input_specs`` supplies precomputed frame embeddings
``[B, seq_len, d_model]`` consumed by the 12-layer encoder; the 12-layer text
decoder (seq_len // 4 targets) cross-attends to the encoder output.
``long_500k`` is skipped for this arch (quadratic enc/cross attention with no
published sub-quadratic variant) — see DESIGN.md §Arch-applicability.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    rope="none",  # learned positions, conformer-style encoder simplified
    act="gelu",
    norm="layernorm",
    encoder_layers=12,
    is_encoder_decoder=True,
    decoder_fraction=4,
    source="arXiv:2308.11596",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512, encoder_layers=2)
