"""stablelm-1.6b [dense] — MHA (kv=heads), partial rotary, layernorm.
[hf:stabilityai/stablelm-2-1_6b]
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    rope="partial",
    rope_fraction=0.25,
    act="swiglu",
    norm="layernorm",
    window_mode="optional",
    source="hf:stabilityai/stablelm-2-1_6b",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512)
