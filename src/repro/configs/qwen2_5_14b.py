"""qwen2.5-14b [dense] — GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    act="swiglu",
    norm="rmsnorm",
    window_mode="optional",
    source="hf:Qwen/Qwen2.5-0.5B",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512)
