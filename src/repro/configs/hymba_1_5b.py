"""hymba-1.5b [hybrid] — parallel attention + mamba heads per block; sliding
window attention everywhere except 3 global layers. [arXiv:2411.13676]
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    act="swiglu",
    norm="rmsnorm",
    ssm_state=16,
    ssm_expand=2,
    window=2048,
    window_mode="all_but_global",
    global_attn_every=16,  # layers 0, 16 (and the last) are global
    source="arXiv:2411.13676",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, ssm_state=8, window=64, global_attn_every=2)
