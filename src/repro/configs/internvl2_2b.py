"""internvl2-2b [vlm] — InternViT (stubbed frontend) + InternLM2 backbone.
[arXiv:2404.16821]

Per the assignment carve-out the ViT is a stub: ``input_specs`` supplies 256
precomputed patch embeddings of width d_model which are prepended to the text
token embeddings.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    act="swiglu",
    norm="rmsnorm",
    window_mode="optional",
    n_patches=256,
    source="arXiv:2404.16821",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, n_patches=8)
