"""dbrx-132b [moe] — 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]"""
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    act="swiglu",
    norm="layernorm",
    window_mode="optional",
    moe=MoEConfig(n_experts=16, top_k=4),
    source="hf:databricks/dbrx-base",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, moe=MoEConfig(n_experts=4, top_k=2))
