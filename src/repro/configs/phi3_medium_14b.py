"""phi3-medium-14b [dense] — RoPE, SwiGLU, GQA kv=10. [arXiv:2404.14219]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    act="swiglu",
    norm="rmsnorm",
    window_mode="optional",
    source="arXiv:2404.14219",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512)
