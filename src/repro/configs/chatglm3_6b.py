"""chatglm3-6b [dense] — RoPE 2d (half-rotary), GQA kv=2. [arXiv:2406.12793]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope="2d-partial",
    rope_fraction=0.5,
    qkv_bias=True,
    act="swiglu",
    norm="rmsnorm",
    window_mode="optional",
    source="arXiv:2406.12793",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512)
