"""xlstm-350m [ssm] — sLSTM + mLSTM blocks, attention-free. [arXiv:2405.04517]

d_ff=0 per the pool: xLSTM blocks carry their own gated up/down projections
(expand factor 2) instead of a separate FFN.  mLSTM blocks use the
chunkwise-parallel matrix-memory form for train/prefill and an O(1) recurrent
state for decode; every ``slstm_every``-th block is an sLSTM (strictly
sequential, ``lax.scan``), xLSTM[7:1] style.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    rope="none",
    act="swiglu",
    norm="layernorm",
    ssm_expand=2,
    slstm_every=8,
    source="arXiv:2405.04517",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
        vocab_size=512, slstm_every=2)
