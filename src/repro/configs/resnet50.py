"""resnet50 [cnn] — the paper's own network (He et al. 2016), used for the
paper-faithful CoDA validation experiments on CIFAR-like synthetic data.

The pool's transformer-oriented fields are repurposed: ``seq_len`` in
``input_specs`` becomes the flattened pixel count (images arrive as
``[B, seq_len, 3]`` and are reshaped to ``[B, H, W, 3]`` with
``H = W = int(sqrt(seq_len))``).
"""
import dataclasses

from repro.configs.base import ModelConfig

# Stage widths follow the standard ResNet50 bottleneck layout; the
# ModelConfig scalar fields are informational for this family.
CONFIG = ModelConfig(
    name="resnet50",
    family="cnn",
    n_layers=50,
    d_model=2048,  # final feature width
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=0,
    rope="none",
    norm="layernorm",
    source="He et al. 2016 (paper's own net)",
)

# (stage blocks, stage width) per ResNet50
RESNET50_STAGES = ((3, 256), (4, 512), (6, 1024), (3, 2048))
RESNET_TINY_STAGES = ((1, 64), (1, 128))


def smoke_config() -> ModelConfig:
    return dataclasses.replace(CONFIG, name="resnet-tiny", n_layers=8, d_model=128)
