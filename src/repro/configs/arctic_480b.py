"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base]
"""
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    act="swiglu",
    norm="rmsnorm",
    window_mode="optional",
    moe=MoEConfig(n_experts=128, top_k=2, dense_residual=True, dense_d_ff=4864),
    source="hf:Snowflake/snowflake-arctic-base",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, dense_residual=True, dense_d_ff=128))
