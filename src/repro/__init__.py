"""repro: CoDA (ICML 2020) — communication-efficient distributed stochastic
AUC maximization — as a production-grade JAX/TPU framework."""
