"""Minimal-but-real pytree checkpointing: npz payload + json manifest.

Layout: ``<dir>/step_<n>/arrays.npz`` + ``manifest.json`` (key-path list,
dtypes, shapes, user metadata).  Restoration requires a template pytree with
the same structure (the usual JAX convention) and verifies shapes/dtypes.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

# npz cannot serialize the ml_dtypes extension types (they round-trip as
# void dtypes that nothing can cast back) — store their raw bits in a
# same-width integer view instead and bitcast on restore.  The manifest
# keeps the REAL dtype name, so restore knows to undo the view; bf16
# optimizer/param buffers round-trip bitwise.
_BITS_VIEW = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = [jax.tree_util.keystr(path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


def save(directory: str, step: int, tree: Any, metadata: dict | None = None) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    keys, leaves, _ = _flatten(tree)
    arrays = {}
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        if a.dtype.name in _BITS_VIEW:
            a = a.view(_BITS_VIEW[a.dtype.name][1])
        arrays[f"a{i}"] = a
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": keys,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
        "metadata": metadata or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return path


def load_metadata(directory: str, step: int) -> dict:
    """The user metadata dict ``save`` stored with this step (the loop
    counters the crash-recovery resume in ``coda.fit`` restarts from)."""
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        return json.load(f)["metadata"]


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None


def restore(directory: str, step: int, template: Any) -> Any:
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    keys, leaves, treedef = _flatten(template)
    if keys != manifest["keys"]:
        raise ValueError(
            f"checkpoint structure mismatch: {set(keys) ^ set(manifest['keys'])}")
    new_leaves = []
    for i, (tmpl, shape) in enumerate(zip(leaves, manifest["shapes"])):
        arr = data[f"a{i}"]
        want = manifest["dtypes"][i]
        if want in _BITS_VIEW and arr.dtype == _BITS_VIEW[want][1]:
            arr = arr.view(_BITS_VIEW[want][0])
        if list(np.shape(tmpl)) != shape:
            raise ValueError(f"shape mismatch at {keys[i]}: "
                             f"{np.shape(tmpl)} vs checkpointed {shape}")
        new_leaves.append(jnp.asarray(arr, dtype=jnp.asarray(tmpl).dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
