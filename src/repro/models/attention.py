"""Grouped-query attention: parameter init, train/prefill apply (delegating
the score/softmax/value contraction to ``repro.kernels.ops.attention``), and
single-token decode against a KV cache.

Cache layouts (per layer):
  * full   — k/v ``[B, S, KV, hd]`` plus ``pos [B, S]`` (position held by each
             slot, -1 = empty).
  * ring   — k/v ``[B, W, KV, hd]`` plus ``pos [B, W]``; slot = position % W.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.embeddings import apply_rope


def init_attention(key, cfg: ModelConfig, *, cross: bool = False, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(kq, (d, H * hd), dtype) * s,
        "wk": jax.random.normal(kk, (d, KV * hd), dtype) * s,
        "wv": jax.random.normal(kv, (d, KV * hd), dtype) * s,
        "wo": jax.random.normal(ko, (H * hd, d), dtype) * (H * hd) ** -0.5,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    del cross  # same shapes for cross attention
    return p


def _project_qkv(cfg: ModelConfig, p, xq, xkv):
    B, Sq = xq.shape[:2]
    Skv = xkv.shape[1]
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, Sq, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def attend(cfg: ModelConfig, p, x, positions, *, window: int | None, causal=True,
           x_kv=None, kv_positions=None, impl="auto", return_kv: bool = False):
    """Train/prefill attention.  ``x``: [B, S, d].  Returns [B, S, d]
    (and, with ``return_kv``, the rotated K/V for cache emission)."""
    xkv = x if x_kv is None else x_kv
    q, k, v = _project_qkv(cfg, p, x, xkv)
    if x_kv is None:  # self attention gets RoPE
        q = apply_rope(cfg, q, positions)
        k = apply_rope(cfg, k, positions if kv_positions is None else kv_positions)
    o = kops.attention(q, k, v, causal=causal, window=window, impl=impl)
    B, S = x.shape[:2]
    out = o.reshape(B, S, cfg.n_heads * cfg.head_dim) @ p["wo"]
    if return_kv:
        return out, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))
    return out


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, B: int, S: int, *, ring: bool, dtype=jnp.bfloat16):
    """KV cache; ``dtype=jnp.int8`` enables the quantized-cache variant
    (beyond-paper §Perf knob): per-(slot, head) fp32 scales, 1 byte/element
    on the HBM stream that dominates decode."""
    W = min(S, cfg.window) if ring else S
    c = {
        "k": jnp.zeros((B, W, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((B, W, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((B, W), -1, jnp.int32),
    }
    if dtype == jnp.int8:
        c["k_scale"] = jnp.zeros((B, W, cfg.n_kv_heads), jnp.float32)
        c["v_scale"] = jnp.zeros((B, W, cfg.n_kv_heads), jnp.float32)
    return c


def _quantize_kv(x):
    """x: [B, KV, hd] -> (int8, scale [B, KV])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def decode_step(cfg: ModelConfig, p, cache, x, positions, *, window: int | None,
                update_cache=True):
    """One-token decode.  ``x``: [B, 1, d]; ``positions``: [B].

    Returns (out [B, 1, d], new_cache).  The cache may be a ring buffer
    (its length < positions is allowed); masking is driven by the per-slot
    ``pos`` array, so stale ring slots and empty slots never contribute.
    """
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(cfg, p, x, x)
    q = apply_rope(cfg, q, positions[:, None])
    k_new = apply_rope(cfg, k_new, positions[:, None])

    W = cache["k"].shape[1]
    slot = positions % W
    bidx = jnp.arange(B)
    quant = cache["k"].dtype == jnp.int8
    new_cache = dict(cache)
    if update_cache:
        if quant:
            kq, ks = _quantize_kv(k_new[:, 0])
            vq, vs = _quantize_kv(v_new[:, 0])
            k_all = cache["k"].at[bidx, slot].set(kq)
            v_all = cache["v"].at[bidx, slot].set(vq)
            new_cache["k_scale"] = cache["k_scale"].at[bidx, slot].set(ks)
            new_cache["v_scale"] = cache["v_scale"].at[bidx, slot].set(vs)
        else:
            k_all = cache["k"].at[bidx, slot].set(k_new[:, 0].astype(cache["k"].dtype))
            v_all = cache["v"].at[bidx, slot].set(v_new[:, 0].astype(cache["v"].dtype))
        pos_all = cache["pos"].at[bidx, slot].set(positions)
    else:
        k_all, v_all, pos_all = cache["k"], cache["v"], cache["pos"]

    # [B, KV, G, hd] grouped query
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q[:, 0].reshape(B, cfg.n_kv_heads, G, cfg.head_dim)
    kf = k_all.astype(jnp.float32)
    vf = v_all.astype(jnp.float32)
    if quant:
        kf = kf * new_cache["k_scale"][..., None]
        vf = vf * new_cache["v_scale"][..., None]
    scores = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                        kf) / (cfg.head_dim ** 0.5)
    valid = (pos_all >= 0) & (pos_all <= positions[:, None])
    if window is not None:
        valid &= pos_all > (positions[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", w, vf)
    o = o.reshape(B, 1, cfg.n_heads * cfg.head_dim).astype(x.dtype)
    out = o @ p["wo"]
    new_cache.update(k=k_all, v=v_all, pos=pos_all)
    return out, new_cache


def cross_decode(cfg: ModelConfig, p, enc_k, enc_v, x):
    """Cross-attention during decode: static encoder K/V, query [B, 1, d]."""
    B = x.shape[0]
    q = (x @ p["wq"] + (p.get("bq", 0.0))).reshape(B, 1, cfg.n_heads, cfg.head_dim)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q[:, 0].reshape(B, cfg.n_kv_heads, G, cfg.head_dim)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                        enc_k.astype(jnp.float32)) / (cfg.head_dim ** 0.5)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", w, enc_v.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.n_heads * cfg.head_dim).astype(x.dtype)
    return o @ p["wo"]
