"""Token embeddings, normalization layers, and rotary position embeddings.

RoPE variants supported:
  * ``1d``         — full-head rotation (Llama-style).
  * ``partial``    — only the first ``rope_fraction`` of head_dim rotates
                     (StableLM-2 uses 25%).
  * ``2d-partial`` — ChatGLM's two-dimensional RoPE: the head is split in
                     half; only the first half rotates (interleaved pairs),
                     the second half passes through.  Functionally this is a
                     half-rotary with interleaved pairing.
  * ``none``       — no rotation (learned/absolute positions or SSM archs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, d: int):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(cfg: ModelConfig, p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(dt)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------
def rope_dims(cfg: ModelConfig) -> int:
    """Number of head dimensions that get rotated (even)."""
    if cfg.rope == "none":
        return 0
    n = int(cfg.head_dim * cfg.rope_fraction)
    return n - (n % 2)


def _angles(positions, n_rot: int, base: float):
    # positions: [...]; returns [..., n_rot // 2]
    inv = 1.0 / (base ** (jnp.arange(0, n_rot, 2, dtype=jnp.float32) / n_rot))
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(cfg: ModelConfig, x, positions):
    """x: [..., S, n_heads, head_dim]; positions: broadcastable to [..., S]."""
    n_rot = rope_dims(cfg)
    if n_rot == 0:
        return x
    ang = _angles(positions, n_rot, cfg.rope_base)  # [..., S, n_rot/2]
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)  # [..., S, 1, n_rot/2]
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    rot, rest = x[..., :n_rot], x[..., n_rot:]
    if cfg.rope == "2d-partial":
        # interleaved pairing (x0,x1),(x2,x3),... — ChatGLM convention
        x1, x2 = rot[..., 0::2], rot[..., 1::2]
        r1 = x1 * cos - x2 * sin
        r2 = x2 * cos + x1 * sin
        rot = jnp.stack([r1, r2], axis=-1).reshape(rot.shape)
    else:
        # half-split pairing (x_i, x_{i+n/2}) — Llama convention
        half = n_rot // 2
        x1, x2 = rot[..., :half], rot[..., half:]
        rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rot, rest], axis=-1) if rest.shape[-1] else rot


# --------------------------------------------------------------------------
# embeddings
# --------------------------------------------------------------------------
def init_embed(key, vocab: int, d: int, dtype=jnp.float32):
    scale = d ** -0.5
    return {"table": jax.random.normal(key, (vocab, d), dtype) * scale}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)
