"""Mixture-of-experts block with top-k routing and two dispatch modes.

Routing is shared (``route``): top-k over a softmax router, gate weights
renormalized over the chosen k.  What differs is how chosen tokens reach
their experts:

* ``capacity`` — padded scatter dispatch into an ``[E, C, d]`` buffer with
  ``C = ceil(T · top_k · capacity_factor / E)`` at train time (over-capacity
  tokens are DROPPED — the standard load-shedding regularizer, and what
  keeps expert FLOPs at the *active* count) or the static dropless bound
  ``C = T`` at eval.  The expert axis is the sharding target for expert
  parallelism (see sharding/rules.py); GSPMD turns the gather/scatter across
  a sharded expert axis into all-to-all style collectives.

* ``sorted`` — dropless sort-based dispatch: the flat ``[T·k]`` (token,
  expert) assignments are argsorted by expert id, per-expert segment sizes
  come from a bincount, the expert MLP runs as a ragged grouped GEMM over
  the sorted ``[T·k, d]`` buffer (``kernels/ops.py::grouped_matmul`` — a
  blocked-scan jnp reference on CPU/GPU, a tile-aligned scalar-prefetch
  Pallas kernel on TPU), and a segment-aware scatter-add combines the
  results.  No ``E``-fold padding:
  at ``C = T`` the capacity buffer is ``E/top_k``-fold oversized in
  expectation (64× on arctic-480b), which is exactly the waste this path
  removes from the eval/decode hot path.

Training always uses ``capacity``; eval/decode use ``cfg.moe.dispatch``
(default ``"sorted"``; ``"capacity"`` keeps the old dropless C = T path).
Both eval modes see bitwise-identical routing decisions — only the
dispatch plumbing differs (``benchmarks/run.py --only moe_dispatch``
measures the wall-clock and buffer-bytes gap).

Arctic-style ``dense_residual`` adds an always-on MLP branch next to the
experts.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.mlp import apply_mlp, init_mlp


def _constrain(x, spec):
    """Optional explicit sharding on MoE intermediates (§Perf knob)."""
    from repro import flags
    if not flags.MOE_SHARDING_CONSTRAINTS:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    d, ff, E = cfg.d_model, cfg.d_ff, m.n_experts
    kr, k1, k2, k3, kd = jax.random.split(key, 5)
    s = d ** -0.5
    p = {
        "router": jax.random.normal(kr, (d, E), jnp.float32) * s,
        "w_gate": jax.random.normal(k1, (E, d, ff), dtype) * s,
        "w_up": jax.random.normal(k2, (E, d, ff), dtype) * s,
        "w_down": jax.random.normal(k3, (E, ff, d), dtype) * ff ** -0.5,
    }
    if m.dense_residual:
        p["dense"] = init_mlp(kd, cfg, d_ff=m.dense_d_ff, dtype=dtype)
    return p


def capacity(cfg: ModelConfig, n_tokens: int, *, train: bool = True) -> int:
    """Per-expert buffer slots for ``capacity`` dispatch: the capacity-factor
    bound at train time, the static dropless bound C = T at eval (dropping
    depends on the token count of the forward pass, so a capacity-limited
    parallel scoring pass and a token-by-token decode would route the same
    sequence differently — tests/test_decode_consistency.py caught exactly
    that divergence on dbrx's top-2-of-4 router)."""
    m = cfg.moe
    c = math.ceil(n_tokens * m.top_k * m.capacity_factor / m.n_experts) \
        if train else n_tokens
    return max(4, c + (-c) % 4)  # pad to a multiple of 4


def dispatch_buffer_bytes(cfg: ModelConfig, n_tokens: int, *,
                          mode: str = "sorted", train: bool = False,
                          dtype=jnp.float32) -> int:
    """Bytes of the per-layer dispatch buffer a forward pass of ``n_tokens``
    allocates under each mode — the quantity the moe_dispatch benchmark and
    scripts/mem_pass.py account for.  ``sorted`` gathers [T·k, d];
    ``capacity`` gathers [E, C, d]."""
    m = cfg.moe
    itemsize = jnp.dtype(dtype).itemsize
    if mode == "sorted":
        return n_tokens * m.top_k * cfg.d_model * itemsize
    if mode == "capacity":
        return (m.n_experts * capacity(cfg, n_tokens, train=train)
                * cfg.d_model * itemsize)
    raise ValueError(f"unknown dispatch mode {mode!r}")


def tokens_per_forward(spec) -> int:
    """Tokens one forward pass dispatches for a benchmark shape spec
    (configs.SHAPES): the full batch for train/prefill, one token per
    sequence for decode.  The single convention behind the moe_dispatch
    benchmark and scripts/mem_pass.py's artifact stamping."""
    return (spec.global_batch if spec.kind == "decode"
            else spec.global_batch * spec.seq_len)


def route(cfg: ModelConfig, p, xf):
    """Shared routing decision.  xf: [T, d] -> (top_g [T, k] fp32 renormed,
    top_e [T, k] int32, gates [T, E] fp32).  Both dispatch modes consume
    exactly this — the modes are bitwise-identical in WHAT they route and
    differ only in how tokens reach the experts."""
    logits = (xf @ p["router"].astype(xf.dtype)).astype(jnp.float32)  # [T, E]
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, cfg.moe.top_k)  # [T, k]
    top_g = top_g / jnp.sum(top_g, axis=-1, keepdims=True)
    return top_g, top_e, gates


def _dispatch_capacity(cfg: ModelConfig, p, xf, top_g, top_e, C: int):
    """Padded scatter dispatch through an [E, C, d] buffer (tokens whose
    expert is over capacity are dropped)."""
    m = cfg.moe
    T, d = xf.shape
    E, k = m.n_experts, m.top_k

    # position of each (token, choice) inside its expert's capacity buffer
    e_flat = top_e.reshape(-1)  # [T*k]
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    g_flat = top_g.reshape(-1)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - onehot,
                              e_flat[:, None], axis=1)[:, 0]
    keep = pos < C
    safe_e = jnp.where(keep, e_flat, E)  # out-of-range => dropped by scatter

    # [E, C] token index / combine weight per expert slot
    idx = jnp.zeros((E, C), jnp.int32).at[safe_e, pos].set(t_flat, mode="drop")
    wgt = jnp.zeros((E, C), jnp.float32).at[safe_e, pos].set(g_flat, mode="drop")

    xe = jnp.take(xf, idx.reshape(-1), axis=0).reshape(E, C, d)  # dispatch
    xe = _constrain(xe, ("data", None, None))
    h = _constrain(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]),
                   ("data", None, "model"))
    u = _constrain(jnp.einsum("ecd,edf->ecf", xe, p["w_up"]),
                   ("data", None, "model"))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"])
    ye = _constrain(ye, ("data", None, None))
    ye = ye * wgt[..., None].astype(ye.dtype)

    return jnp.zeros((T, d), ye.dtype).at[idx.reshape(-1)].add(
        ye.reshape(E * C, d))  # combine


def _dispatch_sorted(cfg: ModelConfig, p, xf, top_g, top_e, *,
                     impl: str = "auto"):
    """Dropless sort-based dispatch: argsort the [T·k] assignments by expert,
    grouped GEMM over the sorted [T·k, d] buffer, segment scatter-add back."""
    m = cfg.moe
    T, d = xf.shape
    E, k = m.n_experts, m.top_k

    e_flat = top_e.reshape(-1)                                # [T*k]
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    g_flat = top_g.reshape(-1)
    order = jnp.argsort(e_flat)                               # stable
    src = t_flat[order]                                       # token per row
    counts = jnp.bincount(e_flat, length=E)                   # segment sizes

    xs = _constrain(jnp.take(xf, src, axis=0), ("data", None))  # [T·k, d]
    wdt = jnp.promote_types(xs.dtype, p["w_gate"].dtype)
    xs = xs.astype(wdt)
    gm = lambda a, w: ops.grouped_matmul(a, w.astype(wdt), counts, impl=impl)
    h = _constrain(gm(xs, p["w_gate"]), ("data", "model"))
    u = _constrain(gm(xs, p["w_up"]), ("data", "model"))
    ys = gm(jax.nn.silu(h) * u, p["w_down"])                  # [T·k, d]
    ys = _constrain(ys, ("data", None))
    ys = ys * g_flat[order][:, None].astype(ys.dtype)

    return jnp.zeros((T, d), ys.dtype).at[src].add(ys)        # combine


def apply_moe(cfg: ModelConfig, p, x, *, train: bool = False,
              impl: str = "auto"):
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    xf = x.reshape(T, d)

    top_g, top_e, gates = route(cfg, p, xf)

    mode = "capacity" if train else m.dispatch
    if mode == "capacity":
        out = _dispatch_capacity(cfg, p, xf, top_g, top_e,
                                 capacity(cfg, T, train=train))
    else:
        out = _dispatch_sorted(cfg, p, xf, top_g, top_e, impl=impl)
    out = out.reshape(B, S, d).astype(x.dtype)

    # Switch-style load-balance auxiliary loss.  ``ce`` counts the dispatched
    # fraction over ALL k choices (normalized by k) so top-2 archs (dbrx,
    # arctic) balance both slots; at k = 1 this reduces exactly to the
    # classic top-1 count (pinned by tests/test_moe_dispatch.py).
    me = jnp.mean(gates, axis=0)  # mean router prob per expert
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1),
                  axis=0) / k
    aux = E * jnp.sum(me * ce)

    if m.dense_residual:
        out = out + apply_mlp(cfg, p["dense"], x)
    return out, aux
