"""Mixture-of-experts block with top-k routing and capacity-based dispatch.

Dispatch is gather/scatter based (not dense one-hot einsum) so the expert
FLOPs are the *active* FLOPs: ``E × C × d × ff`` with
``C = ceil(T · top_k · capacity_factor / E)``.  The expert axis is the
sharding target for expert parallelism (see sharding/rules.py); GSPMD turns
the gather/scatter across a sharded expert axis into all-to-all style
collectives.

Arctic-style ``dense_residual`` adds an always-on MLP branch next to the
experts.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.mlp import apply_mlp, init_mlp


def _constrain(x, spec):
    """Optional explicit sharding on MoE intermediates (§Perf knob)."""
    from repro import flags
    if not flags.MOE_SHARDING_CONSTRAINTS:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    d, ff, E = cfg.d_model, cfg.d_ff, m.n_experts
    kr, k1, k2, k3, kd = jax.random.split(key, 5)
    s = d ** -0.5
    p = {
        "router": jax.random.normal(kr, (d, E), jnp.float32) * s,
        "w_gate": jax.random.normal(k1, (E, d, ff), dtype) * s,
        "w_up": jax.random.normal(k2, (E, d, ff), dtype) * s,
        "w_down": jax.random.normal(k3, (E, ff, d), dtype) * ff ** -0.5,
    }
    if m.dense_residual:
        p["dense"] = init_mlp(kd, cfg, d_ff=m.dense_d_ff, dtype=dtype)
    return p


def capacity(cfg: ModelConfig, n_tokens: int, *, train: bool = True) -> int:
    """Per-expert buffer slots.  Training uses the capacity-factor bound
    (over-capacity tokens are DROPPED — the standard load-shedding
    regularizer, and what keeps expert FLOPs at the *active* count).
    Eval/decode use the dropless bound C = T: dropping depends on the token
    count of the forward pass, so a capacity-limited parallel scoring pass
    and a token-by-token decode would route the same sequence differently
    (tests/test_decode_consistency.py caught exactly that divergence on
    dbrx's top-2-of-4 router).  C = T is the only *static* dropless bound,
    and it is E/top_k-fold oversized in expectation — decode (T = B) and
    the repo's scoring passes are small, but a long-sequence eval on a
    large-E arch pays an [E, T, d] dispatch buffer; a sort-based dropless
    dispatch would remove that waste (see ROADMAP)."""
    m = cfg.moe
    c = math.ceil(n_tokens * m.top_k * m.capacity_factor / m.n_experts) \
        if train else n_tokens
    return max(4, c + (-c) % 4)  # pad to a multiple of 4


def apply_moe(cfg: ModelConfig, p, x, *, train: bool = False):
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    C = capacity(cfg, T, train=train)
    xf = x.reshape(T, d)

    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)  # [T, E]
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, k)  # [T, k]
    top_g = top_g / jnp.sum(top_g, axis=-1, keepdims=True)

    # position of each (token, choice) inside its expert's capacity buffer
    e_flat = top_e.reshape(-1)  # [T*k]
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    g_flat = top_g.reshape(-1)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - onehot,
                              e_flat[:, None], axis=1)[:, 0]
    keep = pos < C
    safe_e = jnp.where(keep, e_flat, E)  # out-of-range => dropped by scatter

    # [E, C] token index / combine weight per expert slot
    idx = jnp.zeros((E, C), jnp.int32).at[safe_e, pos].set(t_flat, mode="drop")
    wgt = jnp.zeros((E, C), jnp.float32).at[safe_e, pos].set(g_flat, mode="drop")

    xe = jnp.take(xf, idx.reshape(-1), axis=0).reshape(E, C, d)  # dispatch
    xe = _constrain(xe, ("data", None, None))
    h = _constrain(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]),
                   ("data", None, "model"))
    u = _constrain(jnp.einsum("ecd,edf->ecf", xe, p["w_up"]),
                   ("data", None, "model"))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"])
    ye = _constrain(ye, ("data", None, None))
    ye = ye * wgt[..., None].astype(ye.dtype)

    out = jnp.zeros((T, d), ye.dtype).at[idx.reshape(-1)].add(
        ye.reshape(E * C, d))  # combine
    out = out.reshape(B, S, d).astype(x.dtype)

    # Switch-style load-balance auxiliary loss
    me = jnp.mean(gates, axis=0)  # mean router prob per expert
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    if m.dense_residual:
        out = out + apply_mlp(cfg, p["dense"], x)
    return out, aux
