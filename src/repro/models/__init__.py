from repro.models import model  # noqa: F401
from repro.models.model import backbone, count_params, init_params, lm_logits, score  # noqa: F401
