"""Per-layer blocks and the scan-over-layers stack.

Uniform architectures (dense / moe / vlm / audio / hybrid) store their layer
parameters *stacked* along a leading ``n_layers`` axis and run under
``jax.lax.scan`` (fast compiles at 28–48 layers, natural remat unit).
Heterogeneous stacks (xLSTM's mLSTM/sLSTM mix) use per-layer parameter lists
and an unrolled Python loop.

Sliding-window vs global attention inside a scanned stack is handled with a
*traced* per-layer window size (``-1`` = global); the jnp chunked-attention
implementation masks with it directly, so hybrid stacks stay scannable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import xlstm as xl
from repro.models.attention import attend, init_attention
from repro.models.embeddings import apply_norm, init_norm
from repro.models.mlp import apply_mlp, init_mlp
from repro.models.moe import apply_moe, init_moe
from repro.models.ssm import apply_ssm, init_ssm


def layer_windows(cfg: ModelConfig, S: int, use_window: bool) -> jnp.ndarray:
    """Per-layer effective window sizes, ``-1`` meaning full/global."""
    if cfg.window_mode == "none" or (cfg.window_mode == "optional" and not use_window):
        return jnp.full((cfg.n_layers,), -1, jnp.int32)
    if cfg.window_mode == "optional":
        return jnp.full((cfg.n_layers,), cfg.window, jnp.int32)
    # all_but_global (hymba): layer 0, every global_attn_every-th, and last are global
    idx = jnp.arange(cfg.n_layers)
    g = (idx % max(cfg.global_attn_every, 1) == 0) | (idx == cfg.n_layers - 1)
    return jnp.where(g, -1, cfg.window).astype(jnp.int32)


def layer_windows_static(cfg: ModelConfig, use_window: bool):
    """Python-level per-layer windows (int | None) for the unrolled decode
    path, mirroring ``layer_windows``."""
    if cfg.window_mode == "none" or (cfg.window_mode == "optional" and not use_window):
        return [None] * cfg.n_layers
    if cfg.window_mode == "optional":
        return [cfg.window] * cfg.n_layers
    out = []
    for i in range(cfg.n_layers):
        g = (i % max(cfg.global_attn_every, 1) == 0) or (i == cfg.n_layers - 1)
        out.append(None if g else cfg.window)
    return out


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_layer(key, cfg: ModelConfig, kind: str, dtype=jnp.float32):
    """kind: decoder | encoder | xdecoder (decoder w/ cross attention)."""
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p = {"norm1": init_norm(cfg, d), "norm2": init_norm(cfg, d)}
    p["attn"] = init_attention(ks[0], cfg, dtype=dtype)
    if kind == "xdecoder":
        p["norm_x"] = init_norm(cfg, d)
        p["cross"] = init_attention(ks[1], cfg, cross=True, dtype=dtype)
    if cfg.family == "moe" and kind == "decoder":
        p["moe"] = init_moe(ks[2], cfg, dtype=dtype)
    else:
        p["mlp"] = init_mlp(ks[3], cfg, dtype=dtype)
    if cfg.family == "hybrid" and kind == "decoder":
        p["ssm"] = init_ssm(ks[4], cfg, dtype=dtype)
        p["norm_h"] = init_norm(cfg, d)
    return p


def init_stack(key, cfg: ModelConfig, n_layers: int, kind: str, dtype=jnp.float32):
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: init_layer(k, cfg, kind, dtype=dtype))(keys)


def xlstm_layer_kinds(cfg: ModelConfig):
    """Static per-layer kind tuple ("mlstm" | "slstm") — xLSTM[7:1] style."""
    kinds = []
    for i in range(cfg.n_layers):
        slstm = cfg.slstm_every > 0 and (i % cfg.slstm_every == cfg.slstm_every - 1)
        kinds.append("slstm" if slstm else "mlstm")
    return tuple(kinds)


def init_xlstm_layers(key, cfg: ModelConfig, dtype=jnp.float32):
    layers = []
    kinds = xlstm_layer_kinds(cfg)
    for kind, k in zip(kinds, jax.random.split(key, cfg.n_layers)):
        core = (xl.init_slstm if kind == "slstm" else xl.init_mlstm)(k, cfg, dtype=dtype)
        layers.append({"norm1": init_norm(cfg, cfg.d_model), "core": core})
    return layers


# --------------------------------------------------------------------------
# apply
# --------------------------------------------------------------------------
def apply_layer(cfg: ModelConfig, p, x, positions, window, *, kind: str,
                causal: bool, enc_out=None, train: bool = False,
                impl: str = "auto", return_kv: bool = False):
    """One block.  ``window``: traced int32 scalar, -1 = full attention.

    Returns (x, aux, kv) where aux is the MoE load-balance loss (0 otherwise)
    and kv the (K, V) pair for cache emission (None unless return_kv).
    """
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["norm1"], x)
    a = attend(cfg, p["attn"], h, positions, window=window, causal=causal,
               impl=impl, return_kv=return_kv)
    kv = None
    if return_kv:
        a, kv = a
    if cfg.family == "hybrid" and "ssm" in p:
        s = apply_ssm(cfg, p["ssm"], apply_norm(cfg, p["norm_h"], x))
        a = 0.5 * (a + s)
    x = x + a
    if "cross" in p:
        hx = apply_norm(cfg, p["norm_x"], x)
        cx = attend(cfg, p["cross"], hx, positions, window=None, causal=False,
                    x_kv=enc_out, impl=impl)
        x = x + cx
    h2 = apply_norm(cfg, p["norm2"], x)
    if "moe" in p:
        y, aux = apply_moe(cfg, p["moe"], h2, train=train, impl=impl)
    else:
        y = apply_mlp(cfg, p["mlp"], h2)
    return x + y, aux, kv


def apply_stack(cfg: ModelConfig, stacked, x, positions, windows, *,
                kind: str = "decoder", causal: bool = True, enc_out=None,
                train: bool = False, impl: str = "auto",
                return_kv: bool = False):
    """Scan the stacked layers.  Returns (hidden, total_aux) — plus stacked
    per-layer (K, V) caches [L, B, S, KV, hd] when ``return_kv`` (the
    inference-prefill path)."""

    def body(carry, layer):
        xc, aux = carry
        lp, w = layer
        xn, a, kv = apply_layer(cfg, lp, xc, positions, w, kind=kind,
                                causal=causal, enc_out=enc_out, train=train,
                                impl=impl, return_kv=return_kv)
        return (xn, aux + a), kv

    if train:
        body = jax.checkpoint(body)
    from repro import flags
    (x, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                 (stacked, windows),
                                 unroll=flags.scan_unroll())
    if return_kv:
        return x, aux, kvs
    return x, aux


def apply_xlstm_layers(cfg: ModelConfig, layers, x):
    for kind, lp in zip(xlstm_layer_kinds(cfg), layers):
        h = apply_norm(cfg, lp["norm1"], x)
        if kind == "slstm":
            x = x + xl.apply_slstm(cfg, lp["core"], h)
        else:
            x = x + xl.apply_mlstm(cfg, lp["core"], h)
    return x, jnp.zeros((), jnp.float32)
