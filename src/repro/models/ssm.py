"""Mamba-style selective state-space layer (Hymba's SSM branch).

Train/prefill uses a parallel linear-recurrence via
``jax.lax.associative_scan`` over the sequence axis; decode keeps an O(1)
recurrent state ``(conv_state, ssm_state)``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _dims(cfg: ModelConfig):
    di = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return di, N, dt_rank


def init_ssm(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    di, N, dt_rank = _dims(cfg)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, di), dtype) * 0.2,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": jax.random.normal(ks[2], (di, dt_rank + 2 * N), dtype) * di ** -0.5,
        "dt_proj": jax.random.normal(ks[3], (dt_rank, di), dtype) * dt_rank ** -0.5,
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01))).astype(dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[4], (di, d), dtype) * di ** -0.5,
    }


def _causal_conv(p, xi):
    """Depthwise causal conv over [B, S, di]."""
    K = p["conv_w"].shape[0]
    pad = jnp.pad(xi, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, k:k + xi.shape[1]] * p["conv_w"][k] for k in range(K))
    return out + p["conv_b"]


def _ssm_inputs(cfg, p, xi):
    di, N, dt_rank = _dims(cfg)
    proj = xi @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"])
    Bc = proj[..., dt_rank:dt_rank + N]
    Cc = proj[..., dt_rank + N:]
    A = -jnp.exp(p["A_log"])  # [di, N] (fp32)
    # keep the recurrence inputs in fp32: associative_scan concatenates the
    # carry pair, so both elements must share one dtype, and the cumulative
    # product is numerically delicate anyway
    dt = dt.astype(jnp.float32)
    dA = jnp.exp(dt[..., None] * A)                        # [..., di, N]
    dBx = ((dt * xi.astype(jnp.float32))[..., None]
           * Bc.astype(jnp.float32)[..., None, :])         # [..., di, N]
    return dA, dBx, Cc


def apply_ssm(cfg: ModelConfig, p, x):
    """x: [B, S, d] -> [B, S, d]."""
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = jax.nn.silu(_causal_conv(p, xi))
    dA, dBx, Cc = _ssm_inputs(cfg, p, xi)  # [B, S, di, N] x2, [B, S, N]

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, Cc.astype(jnp.float32))
    y = (y + p["D"] * xi.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return (y @ p["out_proj"]).astype(x.dtype)


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def init_ssm_state(cfg: ModelConfig, B: int, dtype=jnp.float32):
    di, N, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((B, cfg.ssm_conv - 1, di), dtype),
        "h": jnp.zeros((B, di, N), dtype),
    }


def decode_ssm(cfg: ModelConfig, p, state, x):
    """One-token step.  x: [B, 1, d] -> ([B, 1, d], new_state)."""
    xz = x[:, 0] @ p["in_proj"]
    di = p["in_proj"].shape[1] // 2
    xi, z = xz[:, :di], xz[:, di:]
    hist = jnp.concatenate([state["conv"], xi[:, None]], axis=1)  # [B, K, di]
    xi = jax.nn.silu(jnp.einsum("bkd,kd->bd", hist, p["conv_w"]) + p["conv_b"])
    dA, dBx, Cc = _ssm_inputs(cfg, p, xi)  # [B, di, N] x2, [B, N]
    h = dA * state["h"].astype(dA.dtype) + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32))
    y = (y + p["D"] * xi.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None].astype(x.dtype)
    return out, {"conv": hist[:, 1:], "h": h}
