"""xLSTM blocks: mLSTM (matrix memory, chunkwise/quadratic-parallel for
train & prefill, O(1) recurrent for decode) and sLSTM (strictly sequential
scalar memory, ``lax.scan``).  [arXiv:2405.04517]

The 350M config uses xLSTM[7:1]: every ``slstm_every``-th block is sLSTM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _mdims(cfg: ModelConfig):
    di = cfg.ssm_expand * cfg.d_model
    H = cfg.n_heads
    return di, H, di // H


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------
def init_mlstm(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    di, H, hd = _mdims(cfg)
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (d, di), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, di), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, di), dtype) * s,
        "wz": jax.random.normal(ks[3], (d, di), dtype) * s,  # output gate branch
        "wi": jax.random.normal(ks[4], (d, H), dtype) * s,   # input gate (per head)
        "wf": jax.random.normal(ks[5], (d, H), dtype) * s,   # forget gate (per head)
        "bi": jnp.zeros((H,), jnp.float32),
        "bf": jnp.full((H,), 3.0, jnp.float32),  # forget-open init
        "w_down": jax.random.normal(ks[6], (di, d), dtype) * di ** -0.5,
    }


def _mlstm_qkv(cfg, p, x):
    di, H, hd = _mdims(cfg)
    B, S = x.shape[:2]
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, H, hd) * hd ** -0.5
    v = (x @ p["wv"]).reshape(B, S, H, hd)
    ig = (x @ p["wi"]).astype(jnp.float32) + p["bi"]           # [B, S, H]
    fg = (x @ p["wf"]).astype(jnp.float32) + p["bf"]
    return q, k, v, ig, fg


def apply_mlstm(cfg: ModelConfig, p, x, chunk: int = 256):
    """Chunkwise-parallel mLSTM: sequential ``lax.scan`` over chunks carrying
    the matrix memory, quadratic only within a chunk (O(S·C) memory — this is
    what makes ``prefill_32k``/``long_500k`` feasible for the SSM family).

    x: [B, S, d] -> [B, S, d].
    """
    from repro import flags
    B, S = x.shape[:2]
    di, H, hd = _mdims(cfg)
    C = min(flags.mlstm_chunk(S, chunk), S)
    assert S % C == 0, (S, C)
    q, k, v, ig, fg = _mlstm_qkv(cfg, p, x)
    logf = jax.nn.log_sigmoid(fg)  # [B, S, H]

    def to_chunks(a):  # [B, S, ...] -> [S//C, B, C, ...]
        return jnp.moveaxis(a.reshape(B, S // C, C, *a.shape[2:]), 1, 0)

    qc, kc, vc = map(to_chunks, (q.astype(jnp.float32), k.astype(jnp.float32),
                                 v.astype(jnp.float32)))
    igc, lfc = to_chunks(ig), to_chunks(logf)

    init = (jnp.zeros((B, H, hd, hd), jnp.float32),   # C matrix memory
            jnp.zeros((B, H, hd), jnp.float32),       # n normalizer
            jnp.full((B, H), -1e30, jnp.float32))     # m stabilizer

    tri = jnp.tril(jnp.ones((C, C), bool))

    def step(carry, inp):
        Cm, n, m = carry
        qt, kt, vt, igt, lft = inp                     # [B,C,H,*]
        F = jnp.cumsum(lft, axis=1)                    # inclusive decay  [B,C,H]
        # intra-chunk log gate matrix D[t, j] = F_t - F_j + ig_j  (j <= t)
        D = F[:, :, None, :] - F[:, None, :, :] + igt[:, None, :, :]
        D = jnp.where(tri[None, :, :, None], D, -jnp.inf)
        m_intra = jnp.max(D, axis=2)                   # [B,C,H]
        b = F + m[:, None, :]                          # inter decay at step t
        m_t = jnp.maximum(m_intra, b)                  # combined stabilizer
        Dh = jnp.exp(D - m_t[:, :, None, :])           # [B,C,C,H]
        qk = jnp.einsum("bihd,bjhd->bijh", qt, kt)
        Sm = qk * Dh
        inter_s = jnp.exp(b - m_t)                     # [B,C,H]
        num = (jnp.einsum("bijh,bjhd->bihd", Sm, vt)
               + inter_s[..., None] * jnp.einsum("bihd,bhde->bihe", qt, Cm))
        den = (jnp.sum(Sm, axis=2)
               + inter_s * jnp.einsum("bihd,bhd->bih", qt, n))
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # ---- state update to end of chunk ----
        Ftot = F[:, -1]                                # [B,H]
        g = Ftot[:, None] - F + igt                    # decay of writes to chunk end
        m_new = jnp.maximum(m + Ftot, jnp.max(g, axis=1))
        wr = jnp.exp(g - m_new[:, None])               # [B,C,H]
        Cm_new = (jnp.exp(m + Ftot - m_new)[..., None, None] * Cm
                  + jnp.einsum("bjh,bjhd,bjhe->bhde", wr, kt, vt))
        n_new = (jnp.exp(m + Ftot - m_new)[..., None] * n
                 + jnp.einsum("bjh,bjhd->bhd", wr, kt))
        return (Cm_new, n_new, m_new), h

    _, hs = jax.lax.scan(step, init, (qc, kc, vc, igc, lfc),
                         unroll=flags.scan_unroll())
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, di).astype(x.dtype)
    h = h * jax.nn.silu(x @ p["wz"])
    return h @ p["w_down"]


def init_mlstm_state(cfg: ModelConfig, B: int, dtype=jnp.float32):
    di, H, hd = _mdims(cfg)
    return {
        "C": jnp.zeros((B, H, hd, hd), dtype),
        "n": jnp.zeros((B, H, hd), dtype),
        "m": jnp.full((B, H), -1e30, dtype),
    }


def decode_mlstm(cfg: ModelConfig, p, state, x):
    """One-token recurrent mLSTM.  x: [B, 1, d]."""
    B = x.shape[0]
    di, H, hd = _mdims(cfg)
    q, k, v, ig, fg = _mlstm_qkv(cfg, p, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]          # [B, H, hd]
    ig, fg = ig[:, 0], fg[:, 0]                   # [B, H]
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + state["m"], ig)
    fs = jnp.exp(logf + state["m"] - m_new)[..., None]
    is_ = jnp.exp(ig - m_new)[..., None]
    C = state["C"] * fs[..., None] + is_[..., None] * jnp.einsum(
        "bhk,bhv->bhkv", k.astype(state["C"].dtype), v.astype(state["C"].dtype))
    n = state["n"] * fs + is_ * k.astype(state["n"].dtype)
    num = jnp.einsum("bhkv,bhk->bhv", C, q.astype(C.dtype))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q.astype(n.dtype))),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, di).astype(x.dtype)
    h = h * jax.nn.silu(x @ p["wz"])
    out = h @ p["w_down"]
    return out, {"C": C, "n": n, "m": m_new}


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------
def init_slstm(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 3)
    s = d ** -0.5
    return {
        # fused input projection for (z, i, f, o)
        "w_in": jax.random.normal(ks[0], (d, 4 * d), dtype) * s,
        "b_in": jnp.zeros((4 * d,), jnp.float32),
        # block-diagonal (per-head) recurrent matrices for (z, i, f, o)
        "r": jax.random.normal(ks[1], (4, H, hd, hd), dtype) * hd ** -0.5,
        "w_out": jax.random.normal(ks[2], (d, d), dtype) * s,
    }


def _slstm_cell(cfg, p, carry, u4):
    """carry: (c, n, h, m) each [B, d]; u4: input pre-activations [B, 4d]."""
    c, n, h, m = carry
    B, d = c.shape
    H = cfg.n_heads
    hd = d // H
    hh = h.reshape(B, H, hd)
    rec = jnp.einsum("bhk,ghkl->gbhl", hh, p["r"]).reshape(4, B, d)
    z_, i_, f_, o_ = jnp.split(u4, 4, axis=-1)
    z = jnp.tanh(z_ + rec[0])
    logi = (i_ + rec[1]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid((f_ + rec[2]).astype(jnp.float32))
    o = jax.nn.sigmoid(o_ + rec[3])
    m_new = jnp.maximum(logf + m, logi)
    i_s = jnp.exp(logi - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c_new = f_s * c + i_s * z.astype(jnp.float32)
    n_new = jnp.maximum(f_s * n + i_s, 1e-6)
    h_new = (o.astype(jnp.float32) * c_new / n_new).astype(h.dtype)
    return (c_new, n_new, h_new, m_new), h_new


def apply_slstm(cfg: ModelConfig, p, x):
    """Sequential sLSTM over [B, S, d] via lax.scan."""
    B, S, d = x.shape
    u = x @ p["w_in"] + p["b_in"].astype(x.dtype)  # [B, S, 4d]
    carry = init_slstm_state(cfg, B, d)

    def step(c, u_t):
        return _slstm_cell(cfg, p, c, u_t)

    _, hs = jax.lax.scan(step, carry, jnp.swapaxes(u, 0, 1))
    return jnp.swapaxes(hs, 0, 1) @ p["w_out"]


def init_slstm_state(cfg: ModelConfig, B: int, d: int = 0):
    d = d or cfg.d_model
    z = jnp.zeros((B, d), jnp.float32)
    return (z, z + 1e-6, jnp.zeros((B, d), jnp.float32), z - 1e30)


def decode_slstm(cfg: ModelConfig, p, state, x):
    """One-token sLSTM.  x: [B, 1, d]."""
    u = x[:, 0] @ p["w_in"] + p["b_in"].astype(x.dtype)
    new_state, h = _slstm_cell(cfg, p, state, u)
    return (h @ p["w_out"])[:, None], new_state
