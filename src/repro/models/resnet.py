"""ResNet50 in pure JAX — the paper's own scoring network (He et al. 2016).

BatchNorm is replaced by GroupNorm(32): CoDA is a pure-functional primal-dual
algorithm and running batch statistics would add mutable state that the
paper's analysis (and our worker-averaging) does not model.  This is recorded
as a hardware/framework adaptation in DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.resnet50 import RESNET50_STAGES, RESNET_TINY_STAGES


def _conv_init(key, kh, kw, cin, cout, dtype):
    fan_in = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout), dtype) * (2.0 / fan_in) ** 0.5


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn_init(c):
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


def _gn(p, x, groups=32):
    c = x.shape[-1]
    g = min(groups, c)
    xg = x.reshape(*x.shape[:-1], g, c // g).astype(jnp.float32)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + 1e-5)
    return (xg.reshape(x.shape) * p["scale"] + p["bias"]).astype(x.dtype)


def _stages(cfg: ModelConfig):
    return RESNET_TINY_STAGES if cfg.name == "resnet-tiny" else RESNET50_STAGES


def init_resnet(key, cfg: ModelConfig, dtype=jnp.float32):
    stages = _stages(cfg)
    ks = iter(jax.random.split(key, 4 + sum(n for n, _ in stages) * 4 + 2))
    width0 = stages[0][1] // 4
    p = {"stem": {"w": _conv_init(next(ks), 3, 3, 3, width0, dtype), "gn": _gn_init(width0)},
         "stages": []}
    cin = width0
    for n_blocks, cout in stages:
        mid = cout // 4
        blocks = []
        for b in range(n_blocks):
            blk = {
                "w1": _conv_init(next(ks), 1, 1, cin, mid, dtype), "gn1": _gn_init(mid),
                "w2": _conv_init(next(ks), 3, 3, mid, mid, dtype), "gn2": _gn_init(mid),
                "w3": _conv_init(next(ks), 1, 1, mid, cout, dtype), "gn3": _gn_init(cout),
            }
            if b == 0 and cin != cout:
                blk["wproj"] = _conv_init(next(ks), 1, 1, cin, cout, dtype)
            blocks.append(blk)
            cin = cout
        p["stages"].append(blocks)
    return p


def apply_resnet(cfg: ModelConfig, p, images):
    """images: [B, H, W, 3] -> pooled features [B, d]."""
    x = _gn(p["stem"]["gn"], _conv(images, p["stem"]["w"]))
    x = jax.nn.relu(x)
    for si, blocks in enumerate(p["stages"]):
        for bi, blk in enumerate(blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            h = jax.nn.relu(_gn(blk["gn1"], _conv(x, blk["w1"])))
            h = jax.nn.relu(_gn(blk["gn2"], _conv(h, blk["w2"], stride)))
            h = _gn(blk["gn3"], _conv(h, blk["w3"]))
            sc = x
            if "wproj" in blk:
                sc = _conv(x, blk["wproj"], stride)
            elif stride != 1:
                sc = _conv(x, jnp.eye(x.shape[-1], dtype=x.dtype)[None, None], stride)
            x = jax.nn.relu(h + sc)
    return jnp.mean(x, axis=(1, 2))  # global average pool
