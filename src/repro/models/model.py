"""Top-level model: init / apply for every architecture family.

``score(cfg, params, batch)`` is the scoring function ``h(w; x) ∈ [0, 1]``
that CoDA maximizes AUC for (Assumption 1(iv) of the paper): backbone →
masked mean-pool → linear → sigmoid.  ``lm_logits`` exposes the LM head used
by the serving path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, resnet
from repro.models.embeddings import apply_norm, embed, init_embed, init_norm


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p = {}
    if cfg.family == "cnn":
        p["backbone"] = resnet.init_resnet(ks[0], cfg, dtype=dtype)
        p["score_head"] = _init_score(ks[1], d, dtype)
        return p
    if cfg.family == "mlp":
        dims = [cfg.n_features] + [d] * cfg.n_layers
        p["mlp"] = [
            {"w": jax.random.normal(k, (di, do), dtype) * di ** -0.5,
             "b": jnp.zeros((do,), dtype)}
            for k, di, do in zip(jax.random.split(ks[0], cfg.n_layers),
                                 dims[:-1], dims[1:])]
        p["score_head"] = _init_score(ks[1], d, dtype)
        return p

    p["embed"] = init_embed(ks[0], cfg.vocab_size, d, dtype)
    if cfg.family == "ssm":
        p["layers"] = blocks.init_xlstm_layers(ks[1], cfg, dtype=dtype)
    else:
        p["layers"] = blocks.init_stack(ks[1], cfg, cfg.n_layers,
                                        "xdecoder" if cfg.is_encoder_decoder else "decoder",
                                        dtype=dtype)
    if cfg.is_encoder_decoder:
        p["encoder"] = blocks.init_stack(ks[2], cfg, cfg.encoder_layers, "encoder",
                                         dtype=dtype)
        p["enc_norm"] = init_norm(cfg, d)
        p["enc_in"] = jax.random.normal(ks[5], (d, d), dtype) * d ** -0.5
    if cfg.family == "vlm":
        p["projector"] = jax.random.normal(ks[3], (d, d), dtype) * d ** -0.5
    p["final_norm"] = init_norm(cfg, d)
    p["score_head"] = _init_score(ks[4], d, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(ks[6], (d, cfg.vocab_size), dtype) * d ** -0.5
    return p


def _init_score(key, d, dtype):
    return {"w": jax.random.normal(key, (d, 1), dtype) * d ** -0.5,
            "b": jnp.zeros((1,), jnp.float32)}


# --------------------------------------------------------------------------
# apply
# --------------------------------------------------------------------------
def backbone(cfg: ModelConfig, params, batch, *, use_window: bool = False,
             train: bool = False, impl: str = "auto"):
    """Returns (hidden [B, S', d], moe_aux scalar)."""
    if cfg.family == "cnn":
        images = batch["images"]
        B, s, _ = images.shape
        hw = int(round(s ** 0.5))
        x = images.reshape(B, hw, hw, 3)
        return resnet.apply_resnet(cfg, params["backbone"], x)[:, None, :], jnp.zeros((), jnp.float32)

    if cfg.family == "mlp":
        x = batch["features"]
        for lp in params["mlp"]:
            x = jax.nn.relu(x @ lp["w"] + lp["b"])
        return x[:, None, :], jnp.zeros((), jnp.float32)

    if cfg.family == "audio":
        return _encdec(cfg, params, batch, train=train, impl=impl)

    if cfg.family == "vlm":
        patches = batch["patches"] @ params["projector"]
        tok = embed(params["embed"], batch["tokens"])
        x = jnp.concatenate([patches.astype(tok.dtype), tok], axis=1)
    else:
        x = embed(params["embed"], batch["tokens"])

    B, S = x.shape[:2]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    if cfg.family == "ssm":
        h, aux = blocks.apply_xlstm_layers(cfg, params["layers"], x)
    else:
        windows = blocks.layer_windows(cfg, S, use_window)
        h, aux = blocks.apply_stack(cfg, params["layers"], x, positions, windows,
                                    train=train, impl=impl)
    return apply_norm(cfg, params["final_norm"], h), aux


def _encdec_encoder(cfg: ModelConfig, params, frames, *, train: bool = False,
                    impl: str = "auto"):
    x = frames @ params["enc_in"]
    Se = x.shape[1]
    pos_e = jnp.arange(Se, dtype=jnp.int32)[None, :]
    wins_e = jnp.full((cfg.encoder_layers,), -1, jnp.int32)
    enc, aux_e = blocks.apply_stack(cfg, params["encoder"], x, pos_e, wins_e,
                                    kind="encoder", causal=False, train=train,
                                    impl=impl)
    enc = apply_norm(cfg, params["enc_norm"], enc)
    return enc, aux_e


def _encdec(cfg: ModelConfig, params, batch, *, train: bool, impl: str):
    enc, aux_e = _encdec_encoder(cfg, params, batch["frames"], train=train,
                                 impl=impl)

    tok = embed(params["embed"], batch["tokens"])
    Sd = tok.shape[1]
    pos_d = jnp.arange(Sd, dtype=jnp.int32)[None, :]
    wins_d = jnp.full((cfg.n_layers,), -1, jnp.int32)
    h, aux_d = blocks.apply_stack(cfg, params["layers"], tok, pos_d, wins_d,
                                  kind="xdecoder", causal=True, enc_out=enc,
                                  train=train, impl=impl)
    return apply_norm(cfg, params["final_norm"], h), aux_e + aux_d


def score(cfg: ModelConfig, params, batch, *, use_window: bool = False,
          train: bool = False, impl: str = "auto"):
    """h(w; x) ∈ [0,1] per example.  Returns (scores [B], moe_aux)."""
    h, aux = backbone(cfg, params, batch, use_window=use_window, train=train,
                      impl=impl)
    pooled = jnp.mean(h, axis=1)  # [B, d]
    sh = params["score_head"]
    logit = (pooled @ sh["w"])[:, 0].astype(jnp.float32) + sh["b"][0]
    return jax.nn.sigmoid(logit), aux


def prefill_step(cfg: ModelConfig, params, batch, *, use_window: bool = False,
                 impl: str = "auto"):
    """Inference prefill: forward the full prompt batch, emitting the stacked
    per-layer KV caches [L, B, S, KV, hd] (what a decode session consumes),
    the last-position logits, and the AUC score.

    SSM/xLSTM layers have O(1) recurrent state instead of a length-S cache;
    for those this returns kv=None (state bytes are negligible and the decode
    path rebuilds them)."""
    if cfg.family in ("ssm", "cnn", "mlp"):
        h, _ = backbone(cfg, params, batch, use_window=use_window, impl=impl)
        kv = None
    elif cfg.family == "audio":
        enc, _ = _encdec_encoder(cfg, params, batch["frames"], impl=impl)
        tok = embed(params["embed"], batch["tokens"])
        Sd = tok.shape[1]
        pos_d = jnp.arange(Sd, dtype=jnp.int32)[None, :]
        wins_d = jnp.full((cfg.n_layers,), -1, jnp.int32)
        h, _, kv = blocks.apply_stack(cfg, params["layers"], tok, pos_d, wins_d,
                                      kind="xdecoder", causal=True, enc_out=enc,
                                      impl=impl, return_kv=True)
        h = apply_norm(cfg, params["final_norm"], h)
    else:
        if cfg.family == "vlm":
            patches = batch["patches"] @ params["projector"]
            tok = embed(params["embed"], batch["tokens"])
            x = jnp.concatenate([patches.astype(tok.dtype), tok], axis=1)
        else:
            x = embed(params["embed"], batch["tokens"])
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        windows = blocks.layer_windows(cfg, S, use_window)
        h, _, kv = blocks.apply_stack(cfg, params["layers"], x, positions,
                                      windows, impl=impl, return_kv=True)
        h = apply_norm(cfg, params["final_norm"], h)
    logits = lm_logits(cfg, params, h[:, -1]) if cfg.vocab_size else None
    sh = params["score_head"]
    pooled = jnp.mean(h, axis=1)
    s = jax.nn.sigmoid((pooled @ sh["w"])[:, 0].astype(jnp.float32) + sh["b"][0])
    return s, logits, kv


def lm_logits(cfg: ModelConfig, params, hidden):
    if cfg.tie_embeddings or "lm_head" not in params:
        return hidden @ params["embed"]["table"].T
    return hidden @ params["lm_head"]


# --------------------------------------------------------------------------
# parameter counting (no allocation — eval_shape)
# --------------------------------------------------------------------------
def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    import math
    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes))
    if active_only and cfg.moe is not None:
        m = cfg.moe
        expert_params = 3 * m.n_experts * cfg.d_model * cfg.d_ff * cfg.n_layers
        inactive = expert_params * (1 - m.top_k / m.n_experts)
        total -= int(inactive)
    return int(total)
