"""Feed-forward blocks: SwiGLU (Llama-style) and GeLU (classic)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def init_mlp(key, cfg: ModelConfig, d_ff: int = 0, dtype=jnp.float32):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    s = d ** -0.5
    if cfg.act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": jax.random.normal(k1, (d, ff), dtype) * s,
            "w_up": jax.random.normal(k2, (d, ff), dtype) * s,
            "w_down": jax.random.normal(k3, (ff, d), dtype) * ff ** -0.5,
        }
    k1, k2 = jax.random.split(key)
    return {
        "w_in": jax.random.normal(k1, (d, ff), dtype) * s,
        "w_out": jax.random.normal(k2, (ff, d), dtype) * ff ** -0.5,
    }


def apply_mlp(cfg: ModelConfig, p, x):
    if "w_gate" in p:
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_in"]) @ p["w_out"]
