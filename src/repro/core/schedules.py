"""Stagewise schedules for CoDA (Theorem 1) and the practical variants used
in the paper's experiments (§5: T_s = T₀·3^s, η_s = η₀/3^s, fixed or growing
I).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Stage:
    s: int
    eta: float
    T: int        # inner iterations this stage
    I: int        # communication interval (average every I local steps)
    m: int        # minibatch size for the stage-end α re-estimation


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    n_workers: int
    eta0: float = 0.1
    T0: int = 200
    I0: int = 0            # 0 => Theorem-1 rule I_s = max(1, 1/sqrt(K·η_s))
    m0: int = 64
    mode: str = "practical"  # "practical" (×3 stagewise) | "theorem1"
    # Theorem-1 constants (only used in mode="theorem1")
    mu_over_L: float = 0.05
    p_pos: float = 0.5
    grow_I: bool = False   # Appendix-H variant: I_s = I0 · 3^{s-1}


def theorem1_c(mu_over_L: float) -> float:
    return mu_over_L / (5.0 + mu_over_L)


def stage(cfg: ScheduleConfig, s: int) -> Stage:
    """1-indexed stage s."""
    K = cfg.n_workers
    if cfg.mode == "theorem1":
        c = theorem1_c(cfg.mu_over_L)
        eta = cfg.eta0 * K * math.exp(-(s - 1) * c)
        T = max(1, int(math.ceil(cfg.T0 * math.exp((s - 1) * c) / (cfg.eta0 * K))))
        I = max(1, int(round(1.0 / math.sqrt(K * eta))))
        p = cfg.p_pos
        pt = max(p, 1 - p)
        C = 3 * pt ** (1 / math.log(1 / pt)) / (2 * math.log(1 / pt))
        eta_next = cfg.eta0 * K * math.exp(-s * c)
        T_next = max(1, int(math.ceil(cfg.T0 * math.exp(s * c) / (cfg.eta0 * K))))
        m = int(math.ceil(max(
            (1 + C) / (eta_next ** 2 * T_next * p ** 2 * (1 - p) ** 2),
            math.log(max(K, 2)) / math.log(1 / pt))))
        m = min(m, 100_000)  # practical clamp
        return Stage(s, eta, T, I, max(m, cfg.m0))
    # practical: the paper's experimental setting
    eta = cfg.eta0 / (3 ** (s - 1))
    T = cfg.T0 * (3 ** (s - 1))
    if cfg.I0 <= 0:
        I = max(1, int(round(1.0 / math.sqrt(K * eta))))
    elif cfg.grow_I:
        I = cfg.I0 * (3 ** (s - 1))
    else:
        I = cfg.I0
    return Stage(s, eta, T, min(I, T), cfg.m0)


def stages(cfg: ScheduleConfig, n_stages: int):
    return [stage(cfg, s) for s in range(1, n_stages + 1)]
