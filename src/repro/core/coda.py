"""CoDA — Communication-efficient Distributed primal-dual training (Alg. 1+2)
for pluggable min-max objectives.

The paper proves its communication bound for the min-max AUC objective, but
the construction — I collective-free local primal-dual steps, one averaging
per window, stagewise proximal references — never looks inside the
objective.  This module is written against that seam (``core/objective.py``):
the training state is

    {"params": tree, "duals": dict-pytree, "ref_params": tree,
     "ref_duals": dict-pytree}

where ``duals`` is whatever the configured ``Objective`` declares
(``(a, b, alpha)`` for AUC, ``(a, b, alpha, lam)`` for pAUC-DRO, empty for
BCE) and every layer below — averaging, dtype bucketing, int8 compression,
overlapped rings, sharding rules, HLO payload asserts — works off the tree
*structure*, never off field names.  Select with ``CoDAConfig(objective=...)``.

Representation: every primal/dual variable carries a leading *worker* axis
``K`` (``params[k]`` is machine k's replica, each dual field is [K]).  Local
primal-dual steps are ``vmap``-batched over that axis and therefore contain
no cross-worker collectives; the periodic averaging is a mean over axis 0
(+ broadcast back).

``window_step`` fuses ``I`` local steps (``lax.scan``) with the single
averaging that follows them — one compiled unit per communication window, so
the communication/computation ratio the paper's Theorem 1 is about is
directly visible in the lowered HLO.

Two executors run this algorithm (select with ``fit(..., executor=...)`` or
``make_executor``):

  * ``"vmap"`` (oracle) — this module: the worker axis is a plain batched
    array axis on one device.  Semantically exact, nothing crosses a wire;
    used as the correctness reference.
  * ``"shard_map"`` (production) — ``core/coda_sharded.py``: the worker axis
    is laid out over real mesh devices (``launch/mesh.coda_worker_axes`` +
    ``sharding/rules.py``) with ``jax.shard_map``; the I local steps are
    collective-free and the averaging is ONE bucketed ``lax.pmean``
    all-reduce (or an int8 payload + fp32-scale all-gather pair under
    ``avg_compress="int8"``).  On CPU hosts force a mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the flag must be
    set before the jax backend initialises.

The two paths are equivalence-tested against each other to fp32 tolerance
(tests/test_coda_sharded.py), the generic-dual refactor is pinned against
the legacy scalar-field formulas (tests/test_objective.py), and the
communication accounting below (``comm_rounds`` / ``model_bytes`` /
``comm_bytes``) is cross-checked against the all-reduce ops the compiler
actually emitted (``analysis/hlo.collective_ops``).

Primal update (proximal, footnote 1 of the paper):
    v ← (γ(v − η ∇̂_v F) + η v₀) / (η + γ)
Dual updates are owned by the objective (``Objective.dual_step``): proximal
for its ``prox_refs`` fields, projected descent for min-player auxiliaries,
ascent for the concave duals.

``CoDAConfig(algorithm="codasca")`` swaps the local step for the control-
variate corrected CODASCA variant (core/codasca.py) on either executor —
the heterogeneous-shard regime the paper's analysis excludes.
``CoDAConfig(server_momentum=β)`` additionally applies a server-side
momentum buffer to the averaged iterate (the CODASCA paper's server
update): the buffer is a deterministic function of the synced iterates, so
every worker keeps an identical replica and NOTHING extra crosses the wire
— the window payload asserts are unchanged.  β = 0 is bit-for-bit the plain
path (the momentum arithmetic is never traced).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import objective, optimizer, schedules
from repro.kernels import ops as kops
from repro.models import model as M


@dataclasses.dataclass(frozen=True)
class CoDAConfig:
    n_workers: int
    gamma: float = 0.5          # = 1/(2 L_v); the proximal regularizer weight
    p_pos: float = 0.5          # positive-class prior p
    moe_aux_coef: float = 0.01  # load-balance loss weight (MoE archs)
    use_window: bool = False    # sliding-window attention (long-context)
    impl: str = "auto"          # kernel dispatch (see kernels.ops)
    avg_compress: str = ""      # "" | "int8": compressed worker averaging
    algorithm: str = "coda"     # "coda" | "codasca" (control variates for
                                # heterogeneous shards, core/codasca.py)
    objective: str = "auc"      # which min-max objective to solve
                                # (core/objective.py registry: auc | pauc_dro
                                # | bce)
    pauc_beta: float = 0.3      # FPR budget for objective="pauc_dro"
    server_momentum: float = 0.0  # β: server momentum on the averaged
                                  # iterate (0 = off, bit-for-bit today's
                                  # path; buffer never crosses the wire)
    overlap_chunks: int = 0     # >0: sharded executor lowers the window
                                # averaging as this many ppermute ring
                                # chains per dtype bucket and fit() feeds
                                # fused window PAIRS so the first window's
                                # ring hides under the second's compute
    stream_bins: int = 0        # >0: per-worker streaming-eval score sketch
                                # (repro.metrics.streaming) over the training
                                # scores; the per-window deltas ride the
                                # existing fp32 window bucket as exactly
                                # 2·stream_bins·4 extra bytes — still ONE
                                # all-reduce per window
    stream_range: tuple[float, float] = (-8.0, 8.0)  # sketch score range
    # -- fault tolerance (core/faults.py) ----------------------------------
    # any non-default value below switches both executors to the MASKED
    # window averaging: an exact weighted mean over the participating
    # workers, still one all-reduce per dtype bucket, with a tiny f32
    # weight lane riding the f32 bucket (+4 B, +8 B for CODASCA).  All
    # defaults = faults off = bit-for-bit the classical full-participation
    # path (the masked code is never traced).
    participation: float = 1.0    # per-window per-worker participation prob
    straggler_prob: float = 0.0   # per-window prob a worker's delta is late
    straggler_windows: int = 1    # straggler delay, measured in windows
    max_staleness: int = 0        # merge stale deltas up to this delay;
                                  # beyond it the delta is dropped and the
                                  # worker re-syncs from the merged state
    staleness_discount: float = 0.5  # weight discount per window of delay
                                     # (powers of two stay exact in bf16)
    fault_seed: int = 0           # replay seed for the fault schedule
    crashes: tuple = ()           # ((worker, window), ...) permanent deaths
    param_dtype: Any = jnp.float32
    # -- local primal optimizer (core/optimizer.py registry) ---------------
    # "sgd" is bit-for-bit the plain prox path (no state, nothing extra
    # traced).  Everything else keeps strictly LOCAL per-worker state under
    # state["opt"]: never averaged, never on the wire — the window payload
    # and every HLO byte assert are unchanged for every optimizer.
    optimizer: str = "sgd"        # sgd | momentum | sm3 | shampoo_blocked
    opt_dtype: Any = jnp.float32  # momentum/accumulator storage dtype;
                                  # jnp.bfloat16 halves optimizer state
                                  # (stochastically rounded stores, fp32
                                  # master math in-kernel)
    opt_beta: float = 0.9         # momentum coefficient (optimizer=
                                  # "momentum"; 0 = bit-for-bit sgd)
    opt_eps: float = 1e-6         # preconditioner damping (sm3 / shampoo)
    shampoo_block: int = 32       # block width of the blocked-Shampoo stats
    precond_every: int = 1        # recompute the Shampoo inverse root every
                                  # this many local steps (stale between)

    @property
    def faults_enabled(self) -> bool:
        """True when any fault knob is active — the static switch that
        makes the executors trace the masked window (with the per-window
        fault vectors as a TRACED argument, so the schedule never causes a
        recompile)."""
        return (self.participation < 1.0 or self.straggler_prob > 0.0
                or bool(self.crashes))

    def __post_init__(self):
        # validate once here: the sharded executor dispatches on these with
        # equality checks, and a typo must not silently train plain CoDA
        if self.algorithm not in ("coda", "codasca"):
            raise ValueError(f"unknown algorithm {self.algorithm!r}")
        if self.avg_compress not in ("", "int8"):
            raise ValueError(f"unknown avg_compress {self.avg_compress!r}")
        if self.objective not in objective.names():
            raise ValueError(f"unknown objective {self.objective!r} "
                             f"(registered: {objective.names()})")
        if not 0.0 <= self.server_momentum < 1.0:
            raise ValueError("server_momentum must be in [0, 1), got "
                             f"{self.server_momentum}")
        if not 0.0 < self.pauc_beta <= 1.0:
            raise ValueError(f"pauc_beta must be in (0, 1], got "
                             f"{self.pauc_beta}")
        if self.overlap_chunks < 0:
            raise ValueError(f"overlap_chunks must be >= 0, got "
                             f"{self.overlap_chunks}")
        if self.overlap_chunks and self.avg_compress:
            raise ValueError("overlapped ring averaging ships plain dtype "
                             "buckets; it cannot be combined with "
                             f"avg_compress={self.avg_compress!r}")
        if self.stream_bins < 0:
            raise ValueError(f"stream_bins must be >= 0, got "
                             f"{self.stream_bins}")
        if self.stream_bins and self.avg_compress:
            raise ValueError("the streaming-eval sketch ships raw fp32 "
                             "counts (int8 rounding would corrupt them); it "
                             "cannot be combined with "
                             f"avg_compress={self.avg_compress!r}")
        if self.stream_bins and not self.stream_range[1] > self.stream_range[0]:
            raise ValueError(f"stream_range must satisfy hi > lo, got "
                             f"{self.stream_range}")
        if not 0.0 < self.participation <= 1.0:
            raise ValueError(f"participation must be in (0, 1], got "
                             f"{self.participation}")
        if not 0.0 <= self.straggler_prob < 1.0:
            raise ValueError(f"straggler_prob must be in [0, 1), got "
                             f"{self.straggler_prob}")
        if self.straggler_windows < 1:
            raise ValueError(f"straggler_windows must be >= 1, got "
                             f"{self.straggler_windows}")
        if self.max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0, got "
                             f"{self.max_staleness}")
        if not 0.0 < self.staleness_discount <= 1.0:
            raise ValueError(f"staleness_discount must be in (0, 1], got "
                             f"{self.staleness_discount}")
        if self.faults_enabled and self.server_momentum:
            raise ValueError(
                "server momentum keeps a replicated buffer that assumes "
                "every worker holds the synced iterate after each window; "
                "it cannot be combined with partial participation / fault "
                "injection (participation < 1, stragglers, or crashes)")
        if self.optimizer not in optimizer.names():
            raise ValueError(f"unknown optimizer {self.optimizer!r} "
                             f"(registered: {optimizer.names()})")
        if jnp.dtype(self.opt_dtype) not in (jnp.dtype(jnp.float32),
                                             jnp.dtype(jnp.bfloat16)):
            raise ValueError("opt_dtype must be float32 or bfloat16, got "
                             f"{self.opt_dtype}")
        if not 0.0 <= self.opt_beta < 1.0:
            raise ValueError(f"opt_beta must be in [0, 1), got "
                             f"{self.opt_beta}")
        if self.opt_eps <= 0.0:
            raise ValueError(f"opt_eps must be > 0, got {self.opt_eps}")
        if self.shampoo_block < 1:
            raise ValueError(f"shampoo_block must be >= 1, got "
                             f"{self.shampoo_block}")
        if self.precond_every < 1:
            raise ValueError(f"precond_every must be >= 1, got "
                             f"{self.precond_every}")


# The training state is a plain dict pytree (stacked worker axis throughout).
CoDAState = dict[str, Any]


def init_state(key, mcfg: ModelConfig, ccfg: CoDAConfig) -> CoDAState:
    params = M.init_params(key, mcfg, dtype=ccfg.param_dtype)
    K = ccfg.n_workers
    obj = objective.for_config(ccfg)
    stack = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape).copy(), t)
    # every field gets its own buffer — the jit-once executors donate the
    # state, and donating one aliased buffer twice is a runtime error
    duals = obj.init_duals(K)
    state = {
        "params": stack(params),
        "duals": duals,
        "ref_params": stack(params),
        "ref_duals": {f: jnp.zeros_like(duals[f]) for f in obj.prox_refs},
    }
    if ccfg.server_momentum:
        state["srv_m"] = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), state["params"])
    if ccfg.stream_bins:
        # streaming-eval sketch (repro.metrics.streaming): sk_acc is the
        # replicated global accumulator, sk_new the per-worker delta since
        # the last window average (folded into sk_acc by the collective);
        # sk_loc accumulates each worker's OWN merged deltas locally — the
        # [K, 2, bins] per-shard readout (metrics/report.worker_skew) that
        # costs zero extra wire bytes (sk_new already rides the collective
        # pre-merge; sk_loc never ships)
        z = lambda: jnp.zeros((K, ccfg.stream_bins), jnp.float32)
        state["sk_acc"] = {"pos": z(), "neg": z()}
        state["sk_new"] = {"pos": z(), "neg": z()}
        state["sk_loc"] = {"pos": z(), "neg": z()}
    opt = optimizer.for_config(ccfg).init(ccfg, state["params"])
    if opt is not None:
        state["opt"] = opt
    if ccfg.algorithm == "codasca":
        from repro.core import codasca
        state = codasca.extend_state(state)
    return state


# --------------------------------------------------------------------------
# local primal-dual step (Algorithm 2, lines inside the I-window)
# --------------------------------------------------------------------------
def _worker_loss(mcfg, ccfg, obj, params, duals, batch):
    inputs = {k: v for k, v in batch.items() if k != "labels"}
    h, aux = M.score(mcfg, params, inputs, use_window=ccfg.use_window,
                     train=True, impl=ccfg.impl)
    f = obj.loss(h, batch["labels"], duals)
    return f + ccfg.moe_aux_coef * aux, h


def grad_step_scores(mcfg: ModelConfig, ccfg: CoDAConfig, state: CoDAState,
                     batch):
    """Per-worker losses [K], raw primal/dual gradients (gp, gduals), and
    the batch scores h [K, B] the loss already computed (the streaming-eval
    sketch histograms them — no second forward pass)."""
    obj = objective.for_config(ccfg)
    vg = jax.value_and_grad(
        lambda p_, d_, bt_: _worker_loss(mcfg, ccfg, obj, p_, d_, bt_),
        argnums=(0, 1), has_aux=True)
    (losses, hs), grads = jax.vmap(vg)(state["params"], state["duals"], batch)
    return losses, grads, hs


def grad_step(mcfg: ModelConfig, ccfg: CoDAConfig, state: CoDAState, batch):
    """Per-worker losses [K] + raw primal/dual gradients (gp, gduals).

    ``gduals`` mirrors the objective's dual tree.  Shared by CoDA (applies
    them directly) and CODASCA (applies them with the control-variate
    correction and accumulates the raw values for the window-end variate
    refresh, core/codasca.py)."""
    losses, grads, _ = grad_step_scores(mcfg, ccfg, state, batch)
    return losses, grads


def apply_grads(ccfg: CoDAConfig, state: CoDAState, grads, eta) -> CoDAState:
    """Preconditioned proximal primal descent + the objective's dual step.

    The primal update routes through the optimizer seam
    (``core/optimizer.py``): ``optimizer="sgd"`` has no state (no ``"opt"``
    entry is ever created) and traces exactly the pre-seam
    ``prox_update_tree`` call; stateful optimizers thread their strictly
    local pytree through ``state["opt"]``.  CODASCA enters here with its
    variate-corrected gradients, so the correction composes with any
    optimizer.  The duals keep the objective-owned step — the seam
    preconditions the primal only."""
    gp, gd = grads
    obj = objective.for_config(ccfg)
    opt = optimizer.for_config(ccfg)
    new_params, new_opt = opt.step(ccfg, state.get("opt"), state["params"],
                                   gp, state["ref_params"], eta)
    new_state = dict(state)
    new_state["params"] = new_params
    if new_opt is not None:
        new_state["opt"] = new_opt
    new_state["duals"] = obj.dual_step(state["duals"], gd,
                                       state["ref_duals"], eta, ccfg.gamma)
    return new_state


def local_step(mcfg: ModelConfig, ccfg: CoDAConfig, state: CoDAState, batch,
               eta) -> tuple:
    """One local primal-dual update on every worker (no communication).

    ``batch``: pytree with leading [K, per_worker_batch, ...] axes.
    Returns (new_state, per_worker_losses [K]) — callers that want the
    synchronous scalar take the mean; the sharded executor keeps the vector
    (per-worker loss spread is the heterogeneity signal CODASCA corrects).
    """
    if "sk_new" in state:
        losses, grads, hs = grad_step_scores(mcfg, ccfg, state, batch)
        new = apply_grads(ccfg, state, grads, eta)
        new["sk_new"] = sketch_update(ccfg, state["sk_new"], hs,
                                      batch["labels"])
        return new, losses
    losses, grads = grad_step(mcfg, ccfg, state, batch)
    return apply_grads(ccfg, state, grads, eta), losses


def sketch_update(ccfg: CoDAConfig, sk, hs, labels):
    """Scatter one local step's scores into the per-worker sketch deltas
    ({"pos": [K, B], "neg": [K, B]}); shared by CoDA and CODASCA."""
    from repro.metrics import streaming
    lo, hi = ccfg.stream_range
    upd = jax.vmap(lambda p, n, h, y: streaming.update_counts(
        p, n, h, y, lo, hi))
    pos, neg = upd(sk["pos"], sk["neg"], hs, labels)
    return {"pos": pos, "neg": neg}


def int8_quantize(xf, red_axes):
    """Max-abs int8 quantizer shared by both executors' compressed
    averaging: per-tensor fp32 scale over ``red_axes``, payload in
    [-127, 127].  Change it here and the vmap/shard_map paths stay
    equivalent by construction."""
    scale = jnp.max(jnp.abs(xf), axis=red_axes, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def server_momentum_step(state: CoDAState, start_params, beta: float):
    """Server momentum on the averaged iterate (CODASCA's server update).

    ``start_params`` is the synced iterate the window started from (every
    worker holds the same replica — the invariant each averaging restores),
    ``state["params"]`` the freshly averaged one.  The update

        m ← β·m + (x̄ − x_start),    x ← x_start + m

    runs in fp32 (the buffer is fp32 like CODASCA's variate accumulator)
    and is replicated: m is a deterministic function of synced iterates, so
    all workers compute identical buffers and NO extra bytes cross the wire.
    Callers only trace this when β > 0 — β = 0 stays bit-for-bit the plain
    averaging.
    """
    f32 = lambda t: jax.tree_util.tree_map(
        lambda l: l.astype(jnp.float32), t)
    m = jax.tree_util.tree_map(
        lambda m_, xb, xs: beta * m_ + (xb - xs),
        state["srv_m"], f32(state["params"]), f32(start_params))
    new = dict(state)
    new["srv_m"] = m
    new["params"] = jax.tree_util.tree_map(
        lambda xs, m_, xb: (xs.astype(jnp.float32) + m_).astype(xb.dtype),
        start_params, m, state["params"])
    return new


def average(state: CoDAState, compress: str | None = None) -> CoDAState:
    """Periodic model averaging: one all-reduce over the worker axis.

    Every ``params`` leaf and every dual field is averaged — the payload is
    the tree, whatever the objective put in it.  ``compress="int8"`` is a
    beyond-paper variant (§Perf): every worker quantizes its replica to int8
    with a per-tensor fp32 scale before the cross-worker exchange, so the
    wire format is 1 byte/param instead of 2 (bf16) — at the cost of ~0.4%
    quantization noise on the averaged iterate (bounded, since the local
    drift being averaged is itself O(ηIB) small).
    """
    if compress == "int8":
        def avg(x):
            xf = x.astype(jnp.float32)
            # the int8 tensor is what crosses the worker axis (all-gather);
            # scales are K fp32 scalars
            q, scale = int8_quantize(xf, tuple(range(1, x.ndim)))
            deq = q.astype(jnp.float32) * scale
            m = jnp.mean(deq, axis=0, keepdims=True)
            return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    else:
        avg = lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True),
                                         x.shape)
    new = dict(state)
    new["params"] = jax.tree_util.tree_map(avg, state["params"])
    new["duals"] = jax.tree_util.tree_map(avg, state["duals"])
    if "sk_new" in state:
        new = merge_sketch(new)
    return new


def merge_sketch(state: CoDAState) -> CoDAState:
    """Fold the per-worker sketch deltas into the replicated accumulator at
    a window average: sk_acc += Σ_k sk_new[k] (exact — integer-valued fp32
    counts), then reset the deltas.  The vmap twin of the wire path in
    core/bucketing (which ships n_workers·sk_new through the fp32 mean
    bucket so the collective's mean IS this exact sum)."""
    ssum = jax.tree_util.tree_map(
        lambda l: jnp.sum(l, axis=0, keepdims=True), state["sk_new"])
    new = dict(state)
    new["sk_acc"] = jax.tree_util.tree_map(
        lambda a, s: a + jnp.broadcast_to(s, a.shape), state["sk_acc"], ssum)
    if "sk_loc" in state:
        # per-shard readout: fold each worker's OWN delta into its local
        # history exactly when the delta merges globally (never shipped)
        new["sk_loc"] = jax.tree_util.tree_map(
            lambda c, d: c + d, state["sk_loc"], state["sk_new"])
    new["sk_new"] = jax.tree_util.tree_map(jnp.zeros_like, state["sk_new"])
    return new


def window_step(mcfg: ModelConfig, ccfg: CoDAConfig, state: CoDAState,
                window_batch, eta, *, communicate: bool = True,
                faults=None):
    """``I`` local steps + (optionally) one averaging.

    ``window_batch`` leaves: [I, K, per_worker_batch, ...].  ``I = 1,
    communicate=True`` is exactly NP-PPD-SG; ``K = 1`` is PPD-SG.

    ``faults``: the per-window fault vectors ({"weights": [K] f32,
    "resync": [K] f32}, core/faults.py) switching the averaging to the
    exact masked participant mean — the vmap oracle models the same mask
    semantics as the sharded executor (core/bucketing with ``wa=()``), so
    the two paths stay equivalence-testable under injected faults.
    """

    def body(st, wb):
        st, loss = local_step(mcfg, ccfg, st, wb, eta)
        return st, loss

    from repro import flags
    start_params = state["params"]
    state, losses = jax.lax.scan(body, state, window_batch,
                                 unroll=flags.scan_unroll())
    if communicate:
        if faults is not None:
            from repro.core import bucketing
            state = bucketing.masked_average_state(
                state, faults, (), ccfg.avg_compress or None)
        else:
            state = average(state, compress=ccfg.avg_compress or None)
        if ccfg.server_momentum:  # rejected with faults at config time
            state = server_momentum_step(state, start_params,
                                         ccfg.server_momentum)
    return state, jnp.mean(losses, axis=1)


# --------------------------------------------------------------------------
# stage boundary (Algorithm 1, lines 4–7 + proximal reference update)
# --------------------------------------------------------------------------
def estimate_stage_duals(mcfg: ModelConfig, ccfg: CoDAConfig, params, duals,
                         batch):
    """One worker's stage-boundary dual re-estimates (Alg. 1 lines 4–7 —
    for AUC this is ``optimal_alpha``) from a fresh minibatch.  Returns the
    objective's ``stage_fields`` as a dict of scalars.  Shared by both
    executors so the production shard_map path cannot silently diverge from
    the oracle."""
    obj = objective.for_config(ccfg)
    if not obj.stage_fields:
        return {}
    inputs = {k: v for k, v in batch.items() if k != "labels"}
    h, _ = M.score(mcfg, params, inputs, use_window=ccfg.use_window,
                   train=False, impl=ccfg.impl)
    return obj.stage_duals(h, batch["labels"], duals)


def stage_end(mcfg: ModelConfig, ccfg: CoDAConfig, state: CoDAState, batch,
              *, resync: bool = True):
    """Re-estimate the objective's stage duals from a fresh minibatch on
    every machine (one all-reduce of ``len(stage_fields)`` fp32 scalars) and
    move the proximal references to the averaged iterate.

    ``resync=False`` skips the re-averaging: every window already ends in an
    averaging, so the state entering a stage boundary is synced and the
    re-average is a mathematical no-op that only ships redundant bytes.  The
    jit-once drivers pass False; the default keeps the defensive seed
    behavior for ad-hoc callers.
    """
    obj = objective.for_config(ccfg)
    if resync:
        state = average(state)

    upd = jax.vmap(
        lambda p, d, wb: estimate_stage_duals(mcfg, ccfg, p, d, wb))(
        state["params"], state["duals"], batch)            # {field: [K]}
    new_duals = dict(state["duals"])
    for f, v in upd.items():
        new_duals[f] = jnp.broadcast_to(jnp.mean(v, keepdims=True), v.shape)
    new = dict(state)
    new["duals"] = new_duals
    new["ref_params"] = state["params"]
    new["ref_duals"] = {f: state["duals"][f] for f in obj.prox_refs}
    return new


# --------------------------------------------------------------------------
# accounting + driver
# --------------------------------------------------------------------------
def _payload_leaves(state: CoDAState):
    """The leaves one worker ships per averaging round — every params leaf +
    every dual leaf, in the exact bucket order the wire uses
    (core/bucketing._state_mats flattens the same two-key dict)."""
    return jax.tree_util.tree_leaves(
        {"params": state["params"], "duals": state["duals"]})


def model_bytes(state: CoDAState, compress: str | None = None) -> int:
    """Bytes one worker ships per averaging round (params + dual tree).

    ``compress="int8"``: 1 byte/element payload + one fp32 scale per tensor
    (the wire format of the compressed averaging, matching the int8
    all-gather the sharded executor emits).
    """
    leaves = _payload_leaves(state)
    if compress == "int8":
        per_worker = sum(l.size // l.shape[0] for l in leaves)  # 1 B/elem
        scales = len(leaves) * 4                                # fp32 scales
        return per_worker + scales
    return sum(l.size // l.shape[0] * l.dtype.itemsize for l in leaves)


def opt_state_bytes(state: CoDAState) -> int:
    """Per-worker optimizer-state bytes (``state["opt"]``; 0 for sgd).

    Strictly LOCAL bytes: the wire layout (``bucketing._state_mats``) and
    the payload accounting above flatten only {"params", "duals"}, so by
    construction none of these bytes appear in any window payload — the
    audit's byte-exact collective asserts would fail if they did."""
    return optimizer.state_bytes(state.get("opt"))


# jnp dtype name → the short dtype tag optimized-HLO shapes use
_HLO_DTYPE = {"float32": "f32", "bfloat16": "bf16", "float16": "f16",
              "float64": "f64", "int8": "s8", "int32": "s32"}


def streaming_payload_bytes(state: CoDAState) -> int:
    """Extra fp32 bytes the streaming-eval sketch adds to the window
    collective: the per-worker delta counts (2·stream_bins·4 — pos + neg
    lanes of ``sk_new``).  0 when the sketch is off.  The sketch rides the
    fp32 bucket ONCE (unlike the CODASCA variates it is not doubled: the
    accumulator ``sk_acc`` is replicated and never shipped)."""
    if "sk_new" not in state:
        return 0
    return sum(l.size // l.shape[0] * 4
               for l in jax.tree_util.tree_leaves(state["sk_new"]))


def mask_payload_bytes(state: CoDAState) -> int:
    """Extra f32 bytes the MASKED (partial-participation) window adds to
    the collective: the participation-weight lane (Σu, 4 bytes) plus, for
    CODASCA states, the binary participant-count lane (Σm, 4 more) — the
    two scalars core/bucketing ships inside the f32 bucket so the masked
    mean divides exactly once, after the wire."""
    return 8 if "cv_params" in state else 4


def window_payload_by_dtype(state: CoDAState,
                            compress: str | None = None, *,
                            masked: bool = False) -> dict[str, int]:
    """Window-payload bytes per HLO dtype tag — the per-dtype-bucket view of
    ``window_payload_bytes`` (bucketing ships one collective per dtype, so a
    bf16-param state splits into a bf16 bucket and the f32 dual bucket).
    Works off the payload tree structure, whatever the objective's dual
    layout is.  Only meaningful for the uncompressed layouts (fp-dtype
    pmean or ring).  ``masked=True`` adds the fault-tolerant weight lanes
    to the f32 bucket (``mask_payload_bytes``)."""
    if compress:
        raise ValueError("per-dtype payload is only defined for "
                         "uncompressed averaging")
    mult = 2 if "cv_params" in state else 1
    out: dict[str, int] = {}
    for leaf in _payload_leaves(state):
        tag = _HLO_DTYPE[jnp.dtype(leaf.dtype).name]
        per = leaf.size // leaf.shape[0] * leaf.dtype.itemsize
        out[tag] = out.get(tag, 0) + mult * per
    sk = streaming_payload_bytes(state)
    if sk:
        out["f32"] = out.get("f32", 0) + sk
    if masked:
        out["f32"] = out.get("f32", 0) + mask_payload_bytes(state)
    return out


def window_payload_bytes(state: CoDAState,
                         compress: str | None = None, *,
                         masked: bool = False) -> int:
    """Bytes one worker ships in the single window all-reduce.

    CoDA: exactly ``model_bytes``.  CODASCA (detected by the control-
    variate fields in the state): the per-worker variates ride the same
    bucket, doubling the payload — 2 × model_bytes, still ONE all-reduce
    (asserted against the compiled HLO in tests/test_codasca.py).  The
    streaming-eval sketch (``stream_bins > 0``) adds exactly
    ``streaming_payload_bytes`` fp32 on top (not doubled — the sketch has
    no control variate), asserted in tests/test_metrics.py.  The masked
    (partial-participation) window adds ``mask_payload_bytes`` on top of
    everything — the weight lanes ride the f32 bucket (or the int8 pair's
    f32 scales gather), still the same collective count."""
    mult = 2 if "cv_params" in state else 1
    return (mult * model_bytes(state, compress)
            + streaming_payload_bytes(state)
            + (mask_payload_bytes(state) if masked else 0))


def stage_payload_bytes(ccfg: CoDAConfig) -> int:
    """Bytes one worker ships at a stage boundary: one fp32 scalar per
    objective ``stage_fields`` entry (4 for AUC and pAUC-DRO's α, 0 for the
    dual-free BCE)."""
    return 4 * len(objective.for_config(ccfg).stage_fields)


def comm_rounds(stage_list) -> int:
    """Averaging rounds + one stage-dual all-reduce per stage."""
    return sum(-(-st.T // st.I) + 1 for st in stage_list)


def comm_bytes(stage_list, state: CoDAState,
               compress: str | None = None, *,
               stage_bytes: int = 4) -> int:
    """Total bytes one worker ships over a schedule: one window payload per
    averaging round plus ``stage_bytes`` (one fp32 scalar per stage dual,
    ``stage_payload_bytes``) per stage-end round.  Verified against the
    compiler in tests/test_coda_sharded.py: the window's lowered HLO
    contains exactly one cross-worker all-reduce whose operand bytes are
    ``window_payload_bytes(state)`` (model_bytes for CoDA, 2× for CODASCA),
    and the stage boundary ships the stage scalars."""
    mb = window_payload_bytes(state, compress)
    return sum((-(-st.T // st.I)) * mb + stage_bytes for st in stage_list)


@dataclasses.dataclass
class FitResult:
    state: CoDAState
    history: list          # (stage, iteration, loss)
    comm_rounds: int
    iterations: int
    # per-worker window-payload bytes split by schedule position: a round
    # whose averaging sits in the first half of a fused window pair is
    # *overlapped* (its ring hops can hide under the second window's
    # compute); every other round — second halves, unpaired trailing
    # windows, and all rounds of the non-overlapped executors — is
    # *exposed* latency on the critical path.  The sum is the classical
    # ``comm_bytes`` total; the split is what the overlap buys.
    exposed_bytes: int = 0
    overlapped_bytes: int = 0


class VmapExecutor:
    """The single-device oracle: worker axis = a vmap'd array axis.

    Both executors expose the same surface — ``place(state)``,
    ``window_step(state, wb, eta)``, ``stage_end(state, ab)`` — with the
    step functions jitted exactly once (per window length I, which is a
    shape) and the state buffer donated, so the training loop never
    re-traces and never holds two copies of the model.
    """

    def __init__(self, mcfg: ModelConfig, ccfg: CoDAConfig, *,
                 donate: bool = True):
        self.mcfg, self.ccfg = mcfg, ccfg
        dn = (0,) if donate else ()
        if ccfg.algorithm == "codasca":  # validated by CoDAConfig
            from repro.core import codasca
            wstep = codasca.window_step
        else:
            wstep = window_step
        if ccfg.faults_enabled:
            # the fault vectors are a TRACED argument (shapes fixed at
            # [K]), so the per-window schedule never recompiles anything
            self._wstep = jax.jit(
                lambda st, wb, eta, fl: wstep(mcfg, ccfg, st, wb, eta,
                                              faults=fl),
                donate_argnums=dn)
        else:
            self._wstep = jax.jit(
                lambda st, wb, eta: wstep(mcfg, ccfg, st, wb, eta),
                donate_argnums=dn)
        self._send = jax.jit(
            lambda st, ab: stage_end(mcfg, ccfg, st, ab, resync=False),
            donate_argnums=dn)

    def place(self, state: CoDAState) -> CoDAState:
        return state  # default device placement

    def window_step(self, state: CoDAState, wb, eta, *, faults=None):
        if self.ccfg.faults_enabled:
            if faults is None:
                raise ValueError(
                    "CoDAConfig enables fault injection; window_step needs "
                    "the per-window fault vectors (coda.fit builds them "
                    "from the FaultPlan)")
            return self._wstep(state, wb, eta, faults)
        if faults is not None:
            raise ValueError(
                "fault vectors passed but CoDAConfig has fault injection "
                "disabled (set participation / straggler / crash knobs)")
        return self._wstep(state, wb, eta)

    def stage_end(self, state: CoDAState, ab) -> CoDAState:
        return self._send(state, ab)


def make_executor(mcfg: ModelConfig, ccfg: CoDAConfig, executor: str = "vmap",
                  *, mesh=None, policy: str = "replica", donate: bool = True):
    """The one flag that selects the execution path.

    ``"vmap"`` — single-device oracle (above).  ``"shard_map"`` — the real
    mesh-parallel executor (core/coda_sharded.py); requires ``mesh``.
    """
    if executor == "vmap":
        return VmapExecutor(mcfg, ccfg, donate=donate)
    if executor == "shard_map":
        if mesh is None:
            raise ValueError("executor='shard_map' needs a mesh "
                             "(see launch/mesh.py)")
        from repro.core import coda_sharded
        return coda_sharded.ShardedExecutor(mcfg, ccfg, mesh, policy=policy,
                                            donate=donate)
    raise ValueError(f"unknown executor {executor!r}")


def fit(key, mcfg: ModelConfig, ccfg: CoDAConfig, sched: schedules.ScheduleConfig,
        n_stages: int, sample_window: Callable[[Any, int], Any],
        sample_alpha_batch: Callable[[Any, int], Any],
        eval_every: int = 0,
        eval_fn: Callable[[CoDAState], float] | None = None,
        executor: Any = "vmap", mesh=None, policy: str = "replica",
        fault_plan=None, ckpt_dir: str = "", ckpt_every: int = 0,
        resume: bool = False) -> FitResult:
    """Run CoDA for ``n_stages`` proximal-point stages.

    ``sample_window(key, I)`` must return a batch pytree with leading
    [I, K, B, ...]; ``sample_alpha_batch(key, m)`` one with [K, m, ...].
    ``executor`` is ``"vmap"`` | ``"shard_map"`` or an already-built
    executor object (see ``make_executor``).

    When the executor overlaps (``CoDAConfig(overlap_chunks > 0)`` on the
    sharded executor) the loop feeds fused window PAIRS: one jit call runs
    2·I local steps with the first window's ring averaging scheduled under
    the second window's compute.  An odd trailing window falls back to the
    single-window step; the first-half payloads are accounted as
    ``overlapped_bytes``, everything else as ``exposed_bytes``.

    Fault tolerance: when ``ccfg.faults_enabled`` (or an explicit
    ``fault_plan``, a ``core.faults.FaultPlan``) the loop feeds each window
    its seed-deterministic fault vectors and the executors run the masked
    averaging.  ``ckpt_dir`` + ``ckpt_every`` save ``{"state", "key"}`` +
    the loop counters every ``ckpt_every`` windows (at window boundaries —
    the only points where the state is meaningful to restart from);
    ``resume=True`` restores the latest checkpoint and continues
    bitwise-identically to the uninterrupted run: the PRNG key, the fp32
    state, and the fault schedule (replayed from its seed + global window
    counter) all round-trip exactly (tests/test_checkpoint.py).
    """
    exe = executor if hasattr(executor, "window_step") else \
        make_executor(mcfg, ccfg, executor, mesh=mesh, policy=policy)
    state = exe.place(init_state(key, mcfg, ccfg))
    stage_list = schedules.stages(sched, n_stages)
    if fault_plan is None and ccfg.faults_enabled:
        from repro.core import faults as _faults
        fault_plan = _faults.FaultPlan.from_config(ccfg)
    masked = fault_plan is not None
    history = []
    rounds = 0
    iters = 0
    exposed = overlapped = 0
    gw = 0           # global window counter: fault schedule + ckpt steps
    start_stage = start_w = 0
    payload = window_payload_bytes(state, ccfg.avg_compress or None,
                                   masked=masked)
    stage_payload = stage_payload_bytes(ccfg)
    pairs = getattr(exe, "overlap_pairs", False)

    if ckpt_dir:
        from repro.checkpoint import checkpoint as _ckpt
    if ckpt_dir and resume:
        step = _ckpt.latest_step(ckpt_dir)
        if step is not None:
            restored = _ckpt.restore(ckpt_dir, step,
                                     {"state": state, "key": key})
            meta = _ckpt.load_metadata(ckpt_dir, step)
            state = exe.place(restored["state"])
            key = restored["key"]
            start_stage, start_w = meta["stage"], meta["w"]
            rounds, iters, gw = meta["rounds"], meta["iters"], meta["gw"]
            exposed, overlapped = meta["exposed"], meta["overlapped"]
            history = [tuple(h) for h in meta["history"]]

    def window_faults(w0: int, n: int):
        """Fault vectors for windows w0..w0+n−1 (stacked on a leading pair
        axis when n > 1)."""
        us, rs = zip(*(fault_plan.window(w0 + j) for j in range(n)))
        if n == 1:
            return {"weights": jnp.asarray(us[0]),
                    "resync": jnp.asarray(rs[0])}
        return {"weights": jnp.stack([jnp.asarray(x) for x in us]),
                "resync": jnp.stack([jnp.asarray(x) for x in rs])}

    for si, st in enumerate(stage_list):
        if si < start_stage:
            continue
        n_windows = -(-st.T // st.I)
        w = start_w if si == start_stage else 0
        while w < n_windows:
            key, sk = jax.random.split(key)
            if pairs and w + 1 < n_windows:
                wb = sample_window(sk, 2 * st.I)
                wb = jax.tree_util.tree_map(
                    lambda l: l.reshape((2, st.I) + l.shape[1:]), wb)
                if masked:
                    state, losses = exe.window_pair_step(
                        state, wb, st.eta, faults=window_faults(gw, 2))
                else:
                    state, losses = exe.window_pair_step(state, wb, st.eta)
                rounds += 2
                iters += 2 * st.I
                overlapped += payload
                exposed += payload
                done = 2
                w += 2
                gw += 2
            else:
                wb = sample_window(sk, st.I)
                if masked:
                    state, losses = exe.window_step(
                        state, wb, st.eta, faults=window_faults(gw, 1))
                else:
                    state, losses = exe.window_step(state, wb, st.eta)
                rounds += 1
                iters += st.I
                exposed += payload
                done = 1
                w += 1
                gw += 1
            history.append((st.s, iters, float(jnp.mean(losses))))
            # a pair completes TWO windows in one step: honor the per-window
            # eval cadence if either of them hits it (a mid-pair state does
            # not exist to evaluate, so the pair evals at most once)
            if eval_fn is not None and eval_every and any(
                    j % eval_every == 0 for j in range(w - done + 1, w + 1)):
                history.append((st.s, iters, float(eval_fn(state))))
            if ckpt_dir and ckpt_every and gw % ckpt_every == 0:
                _ckpt.save(ckpt_dir, gw, {"state": state, "key": key},
                           {"stage": si, "w": w, "rounds": rounds,
                            "iters": iters, "gw": gw, "exposed": exposed,
                            "overlapped": overlapped,
                            "history": [list(h) for h in history]})
        key, sk = jax.random.split(key)
        state = exe.stage_end(state, sample_alpha_batch(sk, st.m))
        rounds += 1
        exposed += stage_payload          # the stage-end fp32 dual scalars
    return FitResult(state, history, rounds, iters,
                     exposed_bytes=exposed, overlapped_bytes=overlapped)
