"""Sharded CoDA executor: real mesh-parallel training via ``shard_map``.

The vmap oracle in ``core/coda.py`` *simulates* the K-worker axis as a
batched array axis on one device; nothing about the paper's communication
claims is real there.  This module lays the worker axis over actual mesh
devices (``launch/mesh.coda_worker_axes`` via ``sharding/rules.py``) and
runs the window under ``shard_map``, so the lowered HLO is the paper's
Algorithm 2 made literal:

  * the I local primal-dual steps contain **zero** collectives — each worker
    shard runs them on its own devices;
  * the periodic averaging is **one** all-reduce: every state tensor
    (params + a, b, α) is flattened and concatenated into a single bucket
    per dtype, locally pre-averaged, and ``lax.pmean``-ed over the worker
    axes.  With the default fp32 state that is exactly one all-reduce whose
    operand bytes equal ``coda.model_bytes(state)`` — asserted against the
    compiled HLO in tests/test_coda_sharded.py;
  * with ``CoDAConfig(avg_compress="int8")`` only the int8 payload plus one
    fp32 scale per tensor cross the wire (an s8 all-gather + f32 all-gather
    pair), cutting wire bytes ~4x vs fp32 at ~0.4% quantization noise.

Worker placement follows ``rules.worker_partition``: the "replica" policy
shards workers over (pod?, data); "fsdp" over (pod) only.  When K does not
divide the worker axes (e.g. K=1, the PPD-SG degenerate case) the state is
replicated instead — the executor stays correct with zero collectives.
Within-worker tensor/FSDP parallelism *inside* the manual region is the
multi-host follow-on tracked in ROADMAP.md: jax 0.4.x cannot nest
auto-GSPMD subgroups under a manual worker axis (XLA
``IsManualSubgroup`` check), so trailing dims stay replicated here.

Step functions are jitted once per window length with the state buffer
donated; ``place(state)`` device_puts the state onto the mesh so the loop
steps are pure buffer-in/buffer-out.  Equivalence with the vmap oracle is
tested to fp32 tolerance for both policies and the K=1 / I=1 degenerate
cases on 8 forced host devices.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

try:  # jax >= 0.6 promotes shard_map out of experimental
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.configs.base import ModelConfig
from repro.core import coda
from repro.sharding import rules


# --------------------------------------------------------------------------
# bucketed cross-worker averaging (the one all-reduce per window)
# --------------------------------------------------------------------------
def _pmean_buckets(mats, wa):
    """Mean the [K_loc, n_i] matrices over the global worker axis, shipping
    one concatenated bucket per dtype (one all-reduce each; exactly one for
    the default all-fp32 state).  Returns the [n_i] means."""
    by_dtype = {}
    for i, m in enumerate(mats):
        by_dtype.setdefault(jnp.dtype(m.dtype), []).append(i)
    out = [None] * len(mats)
    for idxs in by_dtype.values():
        buf = jnp.concatenate([mats[i] for i in idxs], axis=1)
        mean = jnp.mean(buf, axis=0)
        if wa:
            mean = jax.lax.pmean(mean, wa)
        offs = np.cumsum([0] + [mats[i].shape[1] for i in idxs])
        for j, i in enumerate(idxs):
            out[i] = mean[offs[j]:offs[j + 1]]
    return out


def _int8_average(mats, wa):
    """Compressed averaging: per-(worker, tensor) max-abs fp32 scales, int8
    payload.  Only the s8 bucket and the fp32 scales cross the wire (one
    all-gather each); dequantize + mean happen on every shard."""
    qs, scales = [], []
    for m in mats:
        q, scale = coda.int8_quantize(m.astype(jnp.float32), (1,))
        qs.append(q)
        scales.append(scale)
    qbuf = jnp.concatenate(qs, axis=1)       # [K_loc, N] int8 payload
    sbuf = jnp.concatenate(scales, axis=1)   # [K_loc, L] fp32 scales
    if wa:
        qbuf = jax.lax.all_gather(qbuf, wa, axis=0, tiled=True)
        sbuf = jax.lax.all_gather(sbuf, wa, axis=0, tiled=True)
    out, off = [], 0
    for i, m in enumerate(mats):
        n = m.shape[1]
        deq = qbuf[:, off:off + n].astype(jnp.float32) * sbuf[:, i:i + 1]
        out.append(jnp.mean(deq, axis=0).astype(m.dtype))
        off += n
    return out


def _bucketed_average(state, wa, compress: Optional[str]):
    """``coda.average`` semantics on a local worker shard: mean over the
    K_loc local workers, then over the worker mesh axes."""
    flat_p, tdef = jax.tree_util.tree_flatten(state["params"])
    kloc = flat_p[0].shape[0]
    mats = [l.reshape(kloc, -1) for l in flat_p] + \
           [state[k].reshape(kloc, 1) for k in ("a", "b", "alpha")]
    means = _int8_average(mats, wa) if compress == "int8" \
        else _pmean_buckets(mats, wa)
    outs = []
    for m, mean in zip(flat_p, means[:len(flat_p)]):
        trail = m.shape[1:]
        outs.append(jnp.broadcast_to(mean.reshape(trail), (kloc,) + trail)
                    .astype(m.dtype))
    new = dict(state)
    new["params"] = jax.tree_util.tree_unflatten(tdef, outs)
    for mean, k in zip(means[len(flat_p):], ("a", "b", "alpha")):
        new[k] = jnp.broadcast_to(mean, (kloc,)).astype(state[k].dtype)
    return new


# --------------------------------------------------------------------------
# executor
# --------------------------------------------------------------------------
class ShardedExecutor:
    """Mesh-parallel CoDA: same surface as ``coda.VmapExecutor``.

    ``window_step`` returns per-worker losses ``[I, K]`` (not the oracle's
    worker-mean ``[I]``): reducing them would cost a second all-reduce in
    the hot window, and the per-worker spread is itself the data-
    heterogeneity signal.  Take ``losses.mean(axis=1)`` to compare.
    """

    def __init__(self, mcfg: ModelConfig, ccfg: coda.CoDAConfig, mesh, *,
                 policy: str = "replica", donate: bool = True):
        self.mcfg, self.ccfg, self.mesh, self.policy = mcfg, ccfg, mesh, policy
        self.worker_axes = rules.worker_partition(mesh, policy, ccfg.n_workers)
        self._donate = (0,) if donate else ()
        self._fns = {}

    # -- spec plumbing ----------------------------------------------------
    def state_shardings(self, state):
        specs = rules.shardmap_state_specs(state, self.mesh, self.policy)
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), specs)

    def place(self, state: coda.CoDAState) -> coda.CoDAState:
        return jax.device_put(state, self.state_shardings(state))

    def _key(self, tag, *trees):
        return (tag,) + tuple(
            (jax.tree_util.tree_structure(t),
             tuple(l.ndim for l in jax.tree_util.tree_leaves(t)))
            for t in trees)

    # -- window -----------------------------------------------------------
    def window_fn(self, state, wb, *, communicate: bool = True):
        """The jitted window step for these arg structures (also the hook
        the HLO tests use: ``.lower(state, wb, eta)``)."""
        key = self._key(("window", communicate), state, wb)
        if key in self._fns:
            return self._fns[key]
        mcfg, ccfg, wa = self.mcfg, self.ccfg, self.worker_axes
        lead = wa if wa else None

        def body(st, bt, eta):
            def step(s, b):
                return coda.local_step(mcfg, ccfg, s, b, eta)

            from repro import flags
            st, losses = jax.lax.scan(step, st, bt,
                                      unroll=flags.scan_unroll())
            if communicate:
                st = _bucketed_average(st, wa, ccfg.avg_compress or None)
            return st, losses  # losses: [I, K_loc]

        st_specs = rules.shardmap_state_specs(state, self.mesh, self.policy)
        bt_specs = rules.shardmap_batch_specs(wb, self.mesh, self.policy,
                                              ccfg.n_workers, worker_dim=1)
        from jax.sharding import PartitionSpec as P
        sm = _shard_map(body, mesh=self.mesh,
                        in_specs=(st_specs, bt_specs, P()),
                        out_specs=(st_specs, P(None, lead)),
                        check_rep=False)
        fn = jax.jit(sm, donate_argnums=self._donate)
        self._fns[key] = fn
        return fn

    def window_step(self, state, wb, eta, *, communicate: bool = True):
        return self.window_fn(state, wb, communicate=communicate)(
            state, wb, eta)

    # -- stage boundary ---------------------------------------------------
    def stage_fn(self, state, ab):
        key = self._key(("stage",), state, ab)
        if key in self._fns:
            return self._fns[key]
        mcfg, ccfg, wa = self.mcfg, self.ccfg, self.worker_axes

        def body(st, batch):
            alphas = jax.vmap(
                lambda p, wb: coda.estimate_alpha(mcfg, ccfg, p, wb))(
                st["params"], batch)                     # [K_loc]
            am = jnp.mean(alphas)
            if wa:
                am = jax.lax.pmean(am, wa)  # the one scalar α all-reduce
            new = dict(st)
            new["alpha"] = jnp.full_like(st["alpha"], am)
            new["ref_params"] = st["params"]
            new["ref_a"] = st["a"]
            new["ref_b"] = st["b"]
            return new

        st_specs = rules.shardmap_state_specs(state, self.mesh, self.policy)
        ab_specs = rules.shardmap_batch_specs(ab, self.mesh, self.policy,
                                              ccfg.n_workers, worker_dim=0)
        sm = _shard_map(body, mesh=self.mesh,
                        in_specs=(st_specs, ab_specs),
                        out_specs=st_specs, check_rep=False)
        fn = jax.jit(sm, donate_argnums=self._donate)
        self._fns[key] = fn
        return fn

    def stage_end(self, state, ab):
        return self.stage_fn(state, ab)(state, ab)
