"""Sharded CoDA executor: real mesh-parallel training via ``shard_map``.

The vmap oracle in ``core/coda.py`` *simulates* the K-worker axis as a
batched array axis on one device; nothing about the paper's communication
claims is real there.  This module lays the worker axis over actual mesh
devices (``launch/mesh.coda_worker_axes`` via ``sharding/rules.py``) and
runs the window under ``shard_map``, so the lowered HLO is the paper's
Algorithm 2 made literal:

  * the I local primal-dual steps contain **zero** collectives — each worker
    shard runs them on its own devices;
  * the periodic averaging is **one** all-reduce: every state tensor
    (params + the objective's dual tree, core/objective.py) is flattened
    and concatenated into a single bucket per dtype, locally pre-averaged,
    and ``lax.pmean``-ed over the worker axes.  The bucket layout is
    derived from tree structure, so any registered objective's duals ride
    it.  With the default fp32 state that is exactly one all-reduce whose
    operand bytes equal ``coda.model_bytes(state)`` — asserted against the
    compiled HLO in tests/test_coda_sharded.py;
  * with ``CoDAConfig(avg_compress="int8")`` only the int8 payload plus one
    fp32 scale per tensor cross the wire (an s8 all-gather + f32 all-gather
    pair), cutting wire bytes ~4x vs fp32 at ~0.4% quantization noise.

Worker placement follows ``rules.worker_partition``: the "replica" policy
shards workers over (pod?, data); "fsdp" over (pod) only.  When K does not
divide the worker axes (e.g. K=1, the PPD-SG degenerate case) the state is
replicated instead — the executor stays correct with zero collectives.
Within-worker tensor/FSDP parallelism *inside* the manual region is the
multi-host follow-on tracked in ROADMAP.md: jax 0.4.x cannot nest
auto-GSPMD subgroups under a manual worker axis (XLA
``IsManualSubgroup`` check), so trailing dims stay replicated here.

Step functions are jitted once per window length with the state buffer
donated; ``place(state)`` device_puts the state onto the mesh so the loop
steps are pure buffer-in/buffer-out.  Equivalence with the vmap oracle is
tested to fp32 tolerance for both policies and the K=1 / I=1 degenerate
cases on 8 forced host devices.

``CoDAConfig(algorithm="codasca")`` swaps the window body for the control-
variate corrected variant (core/codasca.py): still zero collectives inside
the I local steps, still ONE all-reduce per window — the variate refresh
rides the same bucket, doubling its payload (tests/test_codasca.py).

``CoDAConfig(overlap_chunks=C > 0)`` adds the OVERLAPPED schedule: fit()
feeds fused two-window pairs (``window_pair_fn``) in which each averaging
lowers as C ppermute ring chains per dtype bucket
(core/bucketing.ring_mean_buckets) instead of a blocking pmean.  Inside
the fused module the first window's ring hops have only chunk-level data
dependencies against the second window's local steps, so XLA's async
collective-permute scheduling can hide the first averaging's wire time
under compute — the compiled artifact is asserted to be exactly C·2·(R−1)
``collective-permute`` chains per ring interleaved with dot compute and
NO all-reduce (tests/test_overlap.py, analysis/hlo.verify_overlapped_
window).  The ring mean is the same mean; the blocking path stays the
default and the two agree to fp32 tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

try:  # jax >= 0.6 promotes shard_map out of experimental
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.configs.base import ModelConfig
from repro.core import bucketing, coda
from repro.sharding import rules

# The bucketed cross-worker averaging (the one all-reduce per window) lives
# in core/bucketing.py so the vmap oracle and this executor run the same
# arithmetic; the alias keeps the historical test surface.
_bucketed_average = bucketing.average_state


# --------------------------------------------------------------------------
# executor
# --------------------------------------------------------------------------
class ShardedExecutor:
    """Mesh-parallel CoDA: same surface as ``coda.VmapExecutor``.

    ``window_step`` returns per-worker losses ``[I, K]`` (not the oracle's
    worker-mean ``[I]``): reducing them would cost a second all-reduce in
    the hot window, and the per-worker spread is itself the data-
    heterogeneity signal.  Take ``losses.mean(axis=1)`` to compare.
    """

    def __init__(self, mcfg: ModelConfig, ccfg: coda.CoDAConfig, mesh, *,
                 policy: str = "replica", donate: bool = True):
        self.mcfg, self.ccfg, self.mesh, self.policy = mcfg, ccfg, mesh, policy
        self.worker_axes = rules.worker_partition(mesh, policy, ccfg.n_workers)
        self._donate = (0,) if donate else ()
        self._fns = {}
        if ccfg.overlap_chunks and len(self.worker_axes) > 1:
            raise ValueError(
                "overlap_chunks needs the worker axis on ONE mesh axis (a "
                f"ppermute ring has a single total order); partition "
                f"{self.worker_axes} spans {len(self.worker_axes)} axes — "
                "use the fsdp policy or a single-pod mesh")

    def _ring_spec(self):
        """The RingSpec the overlapped averaging runs with, or None when
        overlap is off / there is no wire (replicated K=1 degenerate)."""
        if not self.ccfg.overlap_chunks or not self.worker_axes:
            return None
        ax = self.worker_axes[0]
        return bucketing.RingSpec(ax, self.mesh.shape[ax],
                                  self.ccfg.overlap_chunks)

    @property
    def overlap_pairs(self) -> bool:
        """True when fit() should feed fused window pairs (the overlapped
        schedule).  False on the degenerate no-wire partitions, where a
        ring would be pure overhead."""
        return self._ring_spec() is not None

    # -- spec plumbing ----------------------------------------------------
    def state_shardings(self, state):
        specs = rules.shardmap_state_specs(state, self.mesh, self.policy)
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), specs)

    def place(self, state: coda.CoDAState) -> coda.CoDAState:
        return jax.device_put(state, self.state_shardings(state))

    def _key(self, tag, *trees):
        return (tag,) + tuple(
            (jax.tree_util.tree_structure(t),
             tuple(l.ndim for l in jax.tree_util.tree_leaves(t)))
            for t in trees)

    # -- window -----------------------------------------------------------
    def _one_window(self, st, bt, eta, *, communicate, ring, fl=None):
        """One window's worth of per-shard work: I local steps + (optionally)
        the combined averaging — blocking pmean bucket by default, chunked
        ppermute rings when ``ring`` is given, the masked participant mean
        when ``fl`` (the per-window fault vectors, sliced to this shard's
        workers) is given.  Runs INSIDE shard_map."""
        mcfg, ccfg, wa = self.mcfg, self.ccfg, self.worker_axes
        if ccfg.algorithm == "codasca":
            from repro.core import codasca
            return codasca.run_window(mcfg, ccfg, st, bt, eta, wa=wa,
                                      communicate=communicate, ring=ring,
                                      faults=fl)

        def step(s, b):
            return coda.local_step(mcfg, ccfg, s, b, eta)

        from repro import flags
        start_params = st["params"]
        st, losses = jax.lax.scan(step, st, bt, unroll=flags.scan_unroll())
        if communicate:
            if fl is not None:
                st = bucketing.masked_average_state(
                    st, fl, wa, ccfg.avg_compress or None, ring=ring)
            else:
                st = bucketing.average_state(st, wa,
                                             ccfg.avg_compress or None,
                                             ring=ring,
                                             n_workers=ccfg.n_workers)
            if ccfg.server_momentum:  # rejected with faults at config time
                st = coda.server_momentum_step(st, start_params,
                                               ccfg.server_momentum)
        return st, losses  # losses: [I, K_loc]

    def _fault_specs(self, lead, *, paired: bool = False):
        """PartitionSpecs for the fault-vector dict: each [K] vector is
        sharded over the worker axes exactly like a state leading axis, so
        every shard sees its own workers' weights ([2, K] leaves under the
        fused pair get the worker axis second)."""
        from jax.sharding import PartitionSpec as P
        spec = P(None, lead) if paired else P(lead)
        return {"weights": spec, "resync": spec}

    def window_fn(self, state, wb, *, communicate: bool = True):
        """The jitted window step for these arg structures (also the hook
        the HLO tests use: ``.lower(state, wb, eta)`` — with the fault
        vectors as a fourth traced arg when ``ccfg.faults_enabled``)."""
        key = self._key(("window", communicate), state, wb)
        if key in self._fns:
            return self._fns[key]
        lead = self.worker_axes if self.worker_axes else None
        faulty = self.ccfg.faults_enabled

        if faulty:
            def body(st, bt, eta, fl):
                return self._one_window(st, bt, eta, communicate=communicate,
                                        ring=None, fl=fl)
        else:
            def body(st, bt, eta):
                return self._one_window(st, bt, eta, communicate=communicate,
                                        ring=None)

        st_specs = rules.shardmap_state_specs(state, self.mesh, self.policy)
        bt_specs = rules.shardmap_batch_specs(wb, self.mesh, self.policy,
                                              self.ccfg.n_workers,
                                              worker_dim=1)
        from jax.sharding import PartitionSpec as P
        in_specs = (st_specs, bt_specs, P())
        if faulty:
            in_specs = in_specs + (self._fault_specs(lead),)
        sm = _shard_map(body, mesh=self.mesh,
                        in_specs=in_specs,
                        out_specs=(st_specs, P(None, lead)),
                        check_rep=False)
        fn = jax.jit(sm, donate_argnums=self._donate)
        self._fns[key] = fn
        return fn

    def window_step(self, state, wb, eta, *, communicate: bool = True,
                    faults=None):
        fn = self.window_fn(state, wb, communicate=communicate)
        if self.ccfg.faults_enabled:
            if faults is None:
                raise ValueError(
                    "CoDAConfig enables fault injection; window_step needs "
                    "the per-window fault vectors (coda.fit builds them "
                    "from the FaultPlan)")
            return fn(state, wb, eta, faults)
        if faults is not None:
            raise ValueError(
                "fault vectors passed but CoDAConfig has fault injection "
                "disabled (set participation / straggler / crash knobs)")
        return fn(state, wb, eta)

    # -- fused window pair (the overlapped schedule) ----------------------
    def window_pair_fn(self, state, wb2, *, communicate: bool = True):
        """Two windows fused into ONE compiled unit, with every averaging
        lowered as chunked ppermute rings (``CoDAConfig.overlap_chunks``).

        ``wb2`` leaves carry a leading pair axis: [2, I, K, B, ...].  Inside
        the fused module the first window's ring chains have no barrier
        against the second window's local-step compute — only chunk-level
        data dependencies — so XLA's async collective-permute scheduling
        can hide the first averaging's wire time entirely (that is the
        ``overlapped_bytes`` half of the fit accounting; the second
        window's ring, with nothing after it, stays exposed).  The math is
        the blocking path's math: same bucket, same mean, asserted to fp32
        tolerance in tests/test_overlap.py.
        """
        key = self._key(("pair", communicate), state, wb2)
        if key in self._fns:
            return self._fns[key]
        ring = self._ring_spec()
        lead = self.worker_axes if self.worker_axes else None
        faulty = self.ccfg.faults_enabled

        def run_pair(st, bt2, eta, fl2=None):
            take = lambda t, i: jax.tree_util.tree_map(lambda l: l[i], t)
            flt = lambda i: None if fl2 is None else take(fl2, i)
            st, l1 = self._one_window(st, take(bt2, 0), eta,
                                      communicate=communicate, ring=ring,
                                      fl=flt(0))
            st, l2 = self._one_window(st, take(bt2, 1), eta,
                                      communicate=communicate, ring=ring,
                                      fl=flt(1))
            return st, jnp.concatenate([l1, l2], axis=0)  # [2I, K_loc]

        if faulty:
            def body(st, bt2, eta, fl2):
                return run_pair(st, bt2, eta, fl2)
        else:
            def body(st, bt2, eta):
                return run_pair(st, bt2, eta)

        st_specs = rules.shardmap_state_specs(state, self.mesh, self.policy)
        bt_specs = rules.shardmap_batch_specs(wb2, self.mesh, self.policy,
                                              self.ccfg.n_workers,
                                              worker_dim=2)
        from jax.sharding import PartitionSpec as P
        in_specs = (st_specs, bt_specs, P())
        if faulty:
            in_specs = in_specs + (self._fault_specs(lead, paired=True),)
        sm = _shard_map(body, mesh=self.mesh,
                        in_specs=in_specs,
                        out_specs=(st_specs, P(None, lead)),
                        check_rep=False)
        fn = jax.jit(sm, donate_argnums=self._donate)
        self._fns[key] = fn
        return fn

    def window_pair_step(self, state, wb2, eta, *, communicate: bool = True,
                         faults=None):
        fn = self.window_pair_fn(state, wb2, communicate=communicate)
        if self.ccfg.faults_enabled:
            if faults is None:
                raise ValueError(
                    "CoDAConfig enables fault injection; window_pair_step "
                    "needs the per-window fault vectors (leaves [2, K])")
            return fn(state, wb2, eta, faults)
        if faults is not None:
            raise ValueError(
                "fault vectors passed but CoDAConfig has fault injection "
                "disabled (set participation / straggler / crash knobs)")
        return fn(state, wb2, eta)

    # -- stage boundary ---------------------------------------------------
    def stage_fn(self, state, ab):
        key = self._key(("stage",), state, ab)
        if key in self._fns:
            return self._fns[key]
        mcfg, ccfg, wa = self.mcfg, self.ccfg, self.worker_axes

        from repro.core import objective as OBJ
        obj = OBJ.for_config(ccfg)

        def body(st, batch):
            upd = jax.vmap(
                lambda p, d, wb: coda.estimate_stage_duals(mcfg, ccfg, p, d,
                                                           wb))(
                st["params"], st["duals"], batch)        # {field: [K_loc]}
            upd = {k: jnp.mean(v) for k, v in upd.items()}
            if wa and upd:
                # ONE all-reduce of the stage-dual scalars (a tuple payload
                # of len(stage_fields) fp32 values — 4 bytes for AUC's α)
                upd = jax.lax.pmean(upd, wa)
            new = dict(st)
            new_duals = dict(st["duals"])
            for f, v in upd.items():
                new_duals[f] = jnp.full_like(st["duals"][f], v)
            new["duals"] = new_duals
            new["ref_params"] = st["params"]
            new["ref_duals"] = {f: st["duals"][f] for f in obj.prox_refs}
            return new

        st_specs = rules.shardmap_state_specs(state, self.mesh, self.policy)
        ab_specs = rules.shardmap_batch_specs(ab, self.mesh, self.policy,
                                              ccfg.n_workers, worker_dim=0)
        sm = _shard_map(body, mesh=self.mesh,
                        in_specs=(st_specs, ab_specs),
                        out_specs=st_specs, check_rep=False)
        fn = jax.jit(sm, donate_argnums=self._donate)
        self._fns[key] = fn
        return fn

    def stage_end(self, state, ab):
        return self.stage_fn(state, ab)(state, ab)
