"""Optimizer seam for the local primal update (the CoDA window's inner loop).

CoDA's primal step is the proximal update

    v ← (γ(v − η d) + η v₀) / (η + γ)

where the pre-seam code hard-wired the descent direction ``d = ∇̂_v F``
(plain SGD).  This module makes ``d`` pluggable the same way PR 5 made the
dual tree pluggable: a registry of local optimizers whose state is a
generic pytree threaded through ``CoDAState`` under ``state["opt"]``.

Registered optimizers:

  * ``sgd``             — bit-for-bit the pre-seam path.  ``init`` returns
                          ``None`` and ``core/coda.init_state`` does not add
                          an ``"opt"`` entry at all, so the traced program,
                          the state treedef, the checkpoint manifest, and
                          every HLO payload assert are byte-identical to
                          before the seam existed.
  * ``momentum``        — heavy-ball: m ← β m + g, d = m.  The buffer
                          matches the params tree; with
                          ``opt_dtype=bfloat16`` it is stored stochastically
                          rounded (fp32 master math in the fused kernel),
                          halving optimizer state bytes.
  * ``sm3``             — Anil et al.'s memory-lean adaptive method: one
                          accumulator VECTOR per trailing axis of each leaf
                          (O(Σ dᵢ) state instead of O(Π dᵢ)).  The covering
                          update ν = minⱼ accⱼ + g², d = g·rsqrt(ν + ε)
                          runs through the fused kernel; the per-axis maxes
                          that become the new accumulators reduce outside.
  * ``shampoo_blocked`` — block-diagonal full-matrix preconditioning: the
                          flattened leaf is split into ``shampoo_block``-wide
                          chunks, each with stats G ← G + g gᵀ and a
                          preconditioner G^{-1/2} recomputed every
                          ``precond_every`` local steps via a coupled
                          Newton–Schulz iteration (pure matmuls — no LAPACK
                          custom call, so it traces inside shard_map).  The
                          step is grafted to the diagonal-AdaGrad norm (the
                          stats diagonal is the AdaGrad accumulator), so the
                          rotation comes from the full block statistics and
                          the step-size adaptation from the diagonal.

Key invariants (enforced by tests/test_optimizer.py and the audit):

  * Preconditioning is strictly LOCAL.  Optimizer state lives under
    ``state["opt"]``, which ``core/bucketing._state_mats`` (the wire
    layout) and ``core/coda._payload_leaves`` (the byte accounting) never
    touch — nothing optimizer-shaped can cross the wire by construction,
    and the audit's byte-exact window-payload rule fails if it does.
  * It is never averaged.  Every averaging helper copies the state dict and
    rewrites only params/duals/sketch/variate entries; ``"opt"`` passes
    through untouched on every worker.
  * Absent workers (faults / partial participation) keep their optimizer
    state, and a re-syncing worker adopts the merged iterate but keeps its
    own accumulators (see docs/optimizers.md for why).
  * The duals keep their objective-owned step (``Objective.dual_step``) —
    the seam preconditions the primal only.

State layout (uniform across the non-sgd optimizers)::

    state["opt"] = {"t": [K] int32 local-step counter,
                    "leaves": [per-param-leaf state, ...]}

with ``leaves`` in ``jax.tree_util.tree_leaves(params)`` order.  Every
entry carries the leading worker axis K, so the sharded executor's generic
``P(worker, None, ...)`` specs and the checkpoint round-trip handle it with
no per-optimizer code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref

_GOLD = 0x9E3779B9   # 2^32/φ — the classic Weyl increment
_SALT = 0x85EBCA6B


def _leaf_seed(t, idx: int):
    """Per-(step, leaf) uint32 seed for the stochastic-rounding hash."""
    salt = np.uint32(((idx + 1) * _SALT) & 0xFFFFFFFF)
    return t[0].astype(jnp.uint32) * jnp.uint32(_GOLD) ^ salt


def _flat(params, gp, ref_params, opt):
    leaves, tdef = jax.tree_util.tree_flatten(params)
    return (leaves, jax.tree_util.tree_leaves(gp),
            jax.tree_util.tree_leaves(ref_params), opt["leaves"], tdef)


class _Sgd:
    """The pre-seam path: stateless proximal SGD (d = g)."""

    name = "sgd"

    def init(self, ccfg, params):
        return None

    def step(self, ccfg, opt, params, gp, ref_params, eta):
        new_params = kops.prox_update_tree(params, gp, ref_params, eta,
                                           ccfg.gamma, impl=ccfg.impl)
        return new_params, None


class _Momentum:
    """Heavy-ball momentum through the fused opt_update kernel."""

    name = "momentum"

    def init(self, ccfg, params):
        leaves = jax.tree_util.tree_leaves(params)
        K = leaves[0].shape[0]
        dt = jnp.dtype(ccfg.opt_dtype)
        return {"t": jnp.zeros((K,), jnp.int32),
                "leaves": [jnp.zeros(l.shape, dt) for l in leaves]}

    def step(self, ccfg, opt, params, gp, ref_params, eta):
        vs, gs, rs, bufs, tdef = _flat(params, gp, ref_params, opt)
        t = opt["t"]
        new_v, new_m = [], []
        for i, (v, g, v0, m) in enumerate(zip(vs, gs, rs, bufs)):
            nv, nm = kops.opt_update(v, g, v0, m, eta, ccfg.gamma,
                                     ccfg.opt_beta, _leaf_seed(t, i),
                                     mode="momentum", impl=ccfg.impl)
            new_v.append(nv)
            new_m.append(nm)
        return (jax.tree_util.tree_unflatten(tdef, new_v),
                {"t": t + 1, "leaves": new_m})


class _SM3:
    """SM3-II: per-trailing-axis accumulator vectors, min-of-covers inner
    update fused with the prox projection (kernel mode="precond")."""

    name = "sm3"

    def init(self, ccfg, params):
        leaves = jax.tree_util.tree_leaves(params)
        K = leaves[0].shape[0]
        dt = jnp.dtype(ccfg.opt_dtype)

        def accs(l):
            if l.ndim == 1:      # [K] trailing-scalar leaf: one cell
                return [jnp.zeros((K,), dt)]
            return [jnp.zeros((K, d), dt) for d in l.shape[1:]]

        return {"t": jnp.zeros((K,), jnp.int32),
                "leaves": [accs(l) for l in leaves]}

    def step(self, ccfg, opt, params, gp, ref_params, eta):
        vs, gs, rs, states, tdef = _flat(params, gp, ref_params, opt)
        t = opt["t"]
        dt = jnp.dtype(ccfg.opt_dtype)
        new_v, new_s = [], []
        for i, (v, g, v0, accs) in enumerate(zip(vs, gs, rs, states)):
            seed = _leaf_seed(t, i)
            if v.ndim == 1:
                cover = accs[0].astype(jnp.float32)
            else:
                cover = None
                for j, a in enumerate(accs):
                    shape = [v.shape[0]] + [1] * (v.ndim - 1)
                    shape[1 + j] = v.shape[1 + j]
                    c = a.astype(jnp.float32).reshape(shape)
                    cover = c if cover is None else jnp.minimum(cover, c)
                cover = jnp.broadcast_to(cover, v.shape)
            # fused: ν = cover + g², d = g·rsqrt(ν + ε), prox — one pass;
            # ν comes back fp32 and only its axis maxes are kept
            nv, nu = kops.opt_update(v, g, v0, cover, eta, ccfg.gamma,
                                     ccfg.opt_eps, seed, mode="precond",
                                     impl=ccfg.impl)
            if v.ndim == 1:
                upd = [kref.stochastic_round(nu, seed, dt)]
            else:
                upd = []
                for j in range(v.ndim - 1):
                    red = tuple(ax for ax in range(1, v.ndim) if ax != 1 + j)
                    mx = jnp.max(nu, axis=red)
                    upd.append(kref.stochastic_round(
                        mx, seed + jnp.uint32(j + 1), dt))
            new_v.append(nv)
            new_s.append(upd)
        return (jax.tree_util.tree_unflatten(tdef, new_v),
                {"t": t + 1, "leaves": new_s})


# relative ridge for the blocked-Shampoo inverse root, as a fraction of
# tr(G).  Two jobs: (1) keep bf16-rounded stats (elementwise noise ~0.4%,
# eigenvalue perturbation ≤ 0.4% of the trace) safely PSD so Newton–Schulz
# converges; (2) bound the whitening ratio — G is a sum of FEW outer
# products here (windows are short), so x^{-1/2} with a tiny ridge pumps
# the step's norm budget into noise directions and the grafted signal
# component starves.  sqrt((1+r)/r) ≈ 3.3 at r = 0.1 keeps the
# preconditioner a gentle rotation instead of a noise amplifier.
_SHAMPOO_RIDGE = 0.1


def _inv_sqrt_psd(a, eps: float, iters: int = 15):
    """A^{-1/2} for (nearly) PSD batched [..., b, b] via the coupled
    Newton–Schulz iteration — pure matmuls (no eigh/LAPACK custom call), so
    it traces inside shard_map's manual region and vmap alike.

    The ridge is RELATIVE: δ = ε + ``_SHAMPOO_RIDGE``·tr(A).  bf16-rounded
    stats carry elementwise noise up to ~0.4% of magnitude, which can push
    small eigenvalues slightly negative; for PSD A the perturbation is
    bounded by ‖E‖_F ≤ 0.004·tr(A), so a trace-relative ridge keeps A + δI
    safely positive and the normalized spectrum bounded away from 0 — the
    regime where the iteration provably converges (an absolute ε cannot do
    this: it is dominated by the rounding noise as soon as the stats
    grow).  See ``_SHAMPOO_RIDGE`` for the whitening-vs-noise trade."""
    b = a.shape[-1]
    eye = jnp.eye(b, dtype=jnp.float32)
    tr = jnp.trace(a, axis1=-2, axis2=-1)[..., None, None]
    a = a + (eps + _SHAMPOO_RIDGE * tr) * eye
    c = jnp.trace(a, axis1=-2, axis2=-1)[..., None, None]
    y = a / c
    z = jnp.broadcast_to(eye, a.shape)
    for _ in range(iters):
        t = 0.5 * (3.0 * eye - z @ y)
        y = y @ t
        z = t @ z
    return z * jax.lax.rsqrt(c)


class _ShampooBlocked:
    """Blocked full-matrix preconditioning on the flattened leaf: per-block
    stats G ← G + g gᵀ, preconditioner G^{-1/2} refreshed every
    ``precond_every`` local steps, step grafted to the diagonal-AdaGrad
    norm (see the grafting comment in ``step``)."""

    name = "shampoo_blocked"

    def _geom(self, ccfg, l):
        N = int(np.prod(l.shape[1:])) if l.ndim > 1 else 1
        b = min(ccfg.shampoo_block, N)
        nb = -(-N // b)
        return N, b, nb

    def init(self, ccfg, params):
        leaves = jax.tree_util.tree_leaves(params)
        K = leaves[0].shape[0]
        dt = jnp.dtype(ccfg.opt_dtype)
        out = []
        for l in leaves:
            _, b, nb = self._geom(ccfg, l)
            eye = jnp.broadcast_to(jnp.eye(b, dtype=jnp.float32),
                                   (K, nb, b, b)).astype(dt)
            out.append({"s": jnp.zeros((K, nb, b, b), dt), "p": eye.copy()})
        return {"t": jnp.zeros((K,), jnp.int32), "leaves": out}

    def step(self, ccfg, opt, params, gp, ref_params, eta):
        vs, gs, rs, states, tdef = _flat(params, gp, ref_params, opt)
        t = opt["t"]
        dt = jnp.dtype(ccfg.opt_dtype)
        refresh = (t[0] % ccfg.precond_every) == 0
        new_v, new_s = [], []
        for i, (v, g, v0, st) in enumerate(zip(vs, gs, rs, states)):
            seed = _leaf_seed(t, i)
            K = v.shape[0]
            N, b, nb = self._geom(ccfg, v)
            gf = g.astype(jnp.float32).reshape(K, N)
            gb = jnp.pad(gf, ((0, 0), (0, nb * b - N))).reshape(K, nb, b)
            stats = st["s"].astype(jnp.float32) + jnp.einsum(
                "knb,knc->knbc", gb, gb)
            pre = jax.lax.cond(
                refresh,
                lambda s: _inv_sqrt_psd(s, ccfg.opt_eps),
                lambda s: st["p"].astype(jnp.float32),
                stats)
            db = jnp.einsum("knbc,knc->knb", pre, gb)
            df = db.reshape(K, nb * b)[:, :N]
            # graft the preconditioned DIRECTION onto the diagonal-AdaGrad
            # step's per-worker norm (the stats diagonal IS the AdaGrad
            # accumulator Σg², so it's free): the rotation comes from the
            # full block statistics, the step-size adaptation from the
            # diagonal — and the ε-dominated first steps can't explode by
            # ε^{-1/2} because the grafted norm decays with the accumulator
            diag = jnp.diagonal(stats, axis1=-2, axis2=-1)      # [K, nb, b]
            ga = (gb * jax.lax.rsqrt(diag + ccfg.opt_eps)) \
                .reshape(K, nb * b)[:, :N]
            gn = jnp.sqrt(jnp.sum(ga * ga, axis=1, keepdims=True))
            dn = jnp.sqrt(jnp.sum(df * df, axis=1, keepdims=True))
            d = (df * gn / (dn + 1e-30)).reshape(v.shape)
            nv = kops.prox_update_tree(v, d, v0, eta, ccfg.gamma,
                                       impl=ccfg.impl)
            new_v.append(nv)
            new_s.append({"s": kref.stochastic_round(stats, seed, dt),
                          "p": kref.stochastic_round(
                              pre, seed + jnp.uint32(1), dt)})
        return (jax.tree_util.tree_unflatten(tdef, new_v),
                {"t": t + 1, "leaves": new_s})


_REGISTRY = {o.name: o for o in (_Sgd(), _Momentum(), _SM3(),
                                 _ShampooBlocked())}


def names() -> tuple:
    return tuple(_REGISTRY)


def for_config(ccfg):
    return _REGISTRY[ccfg.optimizer]


def state_bytes(opt_state) -> int:
    """Per-worker optimizer-state bytes (mirrors ``coda.model_bytes``
    accounting: leaf bytes divided by the leading worker axis).  Strictly
    local bytes — by construction NOT part of any window payload."""
    if opt_state is None:
        return 0
    leaves = jax.tree_util.tree_leaves(opt_state)
    return sum(int(np.prod(l.shape[1:])) * jnp.dtype(l.dtype).itemsize
               for l in leaves)


def abstract_state_bytes(ccfg, params) -> int:
    """``state_bytes`` without materializing buffers: ``params`` may be a
    (stacked) tree of ShapeDtypeStructs, e.g. from ``jax.eval_shape``."""
    opt = jax.eval_shape(lambda p: for_config(ccfg).init(ccfg, p), params)
    return state_bytes(opt)
