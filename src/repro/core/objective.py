"""Pluggable min-max objectives for the CoDA executors.

The paper's construction — I collective-free local primal-dual steps, one
averaging per window — never looks inside the objective: it only needs a
scoring model h(w; x), a handful of per-worker dual scalars, and rules for
stepping/averaging them.  This module is that seam.  An ``Objective`` owns

  * ``init_duals(K)``   — the dual state as a dict pytree of [K] fp32 fields
                          (one slot per worker, like every CoDA variable);
  * ``loss(h, y, duals)`` — the saddle function F(w, duals; z), differentiable
                          in ``h`` and every dual leaf (use ``jax.custom_vjp``
                          where closed-form partials exist, as ``auc_F`` does);
  * ``dual_step``       — how dual gradients are applied: proximal descent for
                          fields in ``prox_refs`` (they get a ``ref_duals``
                          slot, reset at stage boundaries), projected descent
                          for fields in ``descent`` (min-player auxiliaries,
                          e.g. the DRO temperature), plain ascent for the rest
                          (the concave duals);
  * ``stage_duals``     — closed-form maximizer re-estimates at a stage
                          boundary (Alg. 1 lines 4-7: ``optimal_alpha``), one
                          fp32 scalar per ``stage_fields`` entry on the wire;
  * ``metric``          — the scalar the objective optimizes for reporting
                          (AUC, partial AUC), built as a mergeable
                          ``repro.metrics.streaming.Metric`` with
                          ``init``/``update``/``merge``/``finalize`` and two
                          backends: ``exact`` (materialise everything —
                          ``roc_auc``/``partial_auc`` below) and ``sketch``
                          (fixed-size streaming histogram).  The old bare
                          ``eval_metric`` callable is removed and raises.

Everything downstream — the vmap oracle and shard_map executors
(core/coda.py, core/coda_sharded.py), CODASCA control variates
(core/codasca.py), dtype-bucket payload accounting and int8 compression
(core/bucketing.py), sharding rules and the HLO payload asserts — works off
the *tree structure* of ``duals``, never off field names, so registering a
new objective touches exactly this file.

Registered objectives:

  * ``auc``      — the Ying et al. 2016 min-max AUC reformulation (paper
                   eq. 2): duals (a, b, α), fused one-pass loss kernel.
  * ``pauc_dro`` — one-way partial AUC via KL-regularized DRO over negatives
                   ("When AUC meets DRO", Zhu et al. 2022): the negative-side
                   expectation of the AUC surrogate is replaced by its KL-DRO
                   value at radius log(1/β) (β = the FPR budget), whose dual
                   temperature λ joins the dual state and is minimized by
                   projected descent; the loss gradient reweights negatives
                   by softmax(ℓ_j/λ) — hard negatives dominate, which is
                   exactly the FPR ≤ β head of the ROC curve.
  * ``bce``      — dual-free logit-space binary cross-entropy (the
                   baseline's loss minimization strawman): ``init_duals`` is
                   the empty tree and the same executors run it with zero
                   dual payload.

``auc_F`` is a differentiable fused primitive: forward and *all* partials
come from one pass over the scores (``kernels.ops.auc_loss`` — Pallas on TPU,
closed-form jnp elsewhere), wired into autodiff with ``jax.custom_vjp``.  The
closed-form partials are exactly the expressions in Appendix B (eq. 34) of
the paper restricted to the scalar head:

    ∂F/∂h = 2(1-p)(h-a)·1⁺ + 2p(h-b)·1⁻ + 2(1+α)(p·1⁻ − (1-p)·1⁺)
    ∂F/∂a = −2(1-p)(h-a)·1⁺        ∂F/∂b = −2p(h-b)·1⁻
    ∂F/∂α = 2(p·h·1⁻ − (1-p)·h·1⁺) − 2p(1-p)α
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

_EPS = 1e-12


@jax.custom_vjp
def auc_F(h, y, a, b, alpha, p):
    """Mean of F(w,a,b,α;z) over the batch.  h: [T] scores, y: [T] ∈ {0,1}."""
    loss, *_ = kops.auc_loss(h, y, a, b, alpha, p)
    return loss


def _fwd(h, y, a, b, alpha, p):
    loss, dh, da, db, dalpha = kops.auc_loss(h, y, a, b, alpha, p)
    return loss, (dh.astype(h.dtype), da, db, dalpha)


def _bwd(res, ct):
    dh, da, db, dalpha = res
    return (ct * dh, None, ct * da, ct * db, ct * dalpha, None)


auc_F.defvjp(_fwd, _bwd)


def optimal_alpha(h, y, eps: float = _EPS):
    """Closed-form maximizer α*(v) = E[h|y=-1] − E[h|y=1] (paper eq. 8),
    estimated on a batch — this is Algorithm 1 lines 4–7 for one machine."""
    h = h.astype(jnp.float32)
    pos = y.astype(jnp.float32)
    neg = 1.0 - pos
    mean_neg = jnp.sum(h * neg) / jnp.maximum(jnp.sum(neg), eps)
    mean_pos = jnp.sum(h * pos) / jnp.maximum(jnp.sum(pos), eps)
    return mean_neg - mean_pos


# --------------------------------------------------------------------------
# evaluation metrics
# --------------------------------------------------------------------------
def roc_auc(scores, labels):
    """Exact (tie-aware) empirical AUC via rank statistics.

    Tied scores contribute 1/2 per pair (average ranks).  Degenerate
    single-class batches (no positives or no negatives) return 0.0 — there
    are no pairs to rank, and callers treat the value as "undefined, worst".
    Pinned against the O(n²) pairwise oracle in tests/test_objective.py.
    """
    s = scores.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    order = jnp.argsort(s)
    ss = s[order]
    ranks1 = jnp.arange(1, s.shape[0] + 1, dtype=jnp.float32)
    # average ranks over ties
    first = jnp.searchsorted(ss, ss, side="left").astype(jnp.float32) + 1
    last = jnp.searchsorted(ss, ss, side="right").astype(jnp.float32)
    avg_rank_sorted = 0.5 * (first + last)
    ranks = jnp.zeros_like(ranks1).at[order].set(avg_rank_sorted)
    n_pos = jnp.sum(y)
    n_neg = jnp.sum(1.0 - y)
    sum_pos_ranks = jnp.sum(ranks * y)
    return (sum_pos_ranks - n_pos * (n_pos + 1) / 2) / jnp.maximum(n_pos * n_neg, _EPS)


def partial_auc(scores, labels, beta: float = 0.3):
    """One-way partial AUC at FPR ≤ ``beta``, normalized to [0, 1].

    Positives are ranked against only the hardest ⌈β·n⁻⌉ negatives (the
    top-scoring ones — the negatives that populate the FPR ≤ β head of the
    ROC curve); ties count 1/2.  Runs in NumPy (an eval-time metric, never
    traced).  Degenerate single-class inputs return 0.0, matching
    ``roc_auc``'s convention.  Pinned against the O(n²) pairwise oracle in
    tests/test_objective.py.
    """
    s = np.asarray(scores, np.float64)
    y = np.asarray(labels, np.float64)
    sp = s[y > 0.5]
    sn = s[y <= 0.5]
    if len(sp) == 0 or len(sn) == 0:
        return 0.0
    k = max(1, int(np.ceil(beta * len(sn))))
    hard = np.sort(sn)[::-1][:k]        # hardest k negatives by score
    # tie-aware AUC of positives vs the hard-negative subset, via ranks on
    # the pooled vector (same formula as roc_auc, subset-restricted)
    pooled = np.concatenate([sp, hard])
    order = np.argsort(pooled, kind="mergesort")
    sorted_ = pooled[order]
    first = np.searchsorted(sorted_, sorted_, side="left") + 1
    last = np.searchsorted(sorted_, sorted_, side="right")
    ranks = np.empty_like(pooled)
    ranks[order] = 0.5 * (first + last)
    n_pos = float(len(sp))
    sum_pos_ranks = float(ranks[:len(sp)].sum())
    return float((sum_pos_ranks - n_pos * (n_pos + 1) / 2) / (n_pos * k))


# --------------------------------------------------------------------------
# the Objective seam
# --------------------------------------------------------------------------
class Objective:
    """One min-max objective: dual state + loss + update/boundary rules.

    Subclasses set the class attributes and implement ``loss`` /
    ``stage_duals``; ``dual_step`` has a generic implementation driven by
    the field sets (override ``project`` for constrained descent fields).
    Instances are cheap immutable config holders — built per trace via
    ``for_config`` and closed over, never passed as jit arguments.
    """

    name: str = ""
    prox_refs: tuple[str, ...] = ()     # duals under proximal regularization
    descent: tuple[str, ...] = ()       # min-player duals (projected descent)
    stage_fields: tuple[str, ...] = ()  # duals re-estimated at stage ends
    metric_name: str = "auc"

    def init_duals(self, K: int) -> dict[str, jax.Array]:
        raise NotImplementedError

    def loss(self, h, y, duals):
        """F(w, duals; z) for one worker's batch: h [T] scores, y [T] labels,
        duals a dict of scalars (the worker axis is vmapped away)."""
        raise NotImplementedError

    def dual_step(self, duals, grads, ref_duals, eta, gamma):
        """Apply one step of dual gradients: prox for ``prox_refs`` fields
        (against their ``ref_duals`` slot), projected descent for
        ``descent`` fields, ascent for the concave rest."""
        new = {}
        for k, v in duals.items():
            if k in self.prox_refs:
                new[k] = (gamma * (v - eta * grads[k])
                          + eta * ref_duals[k]) / (eta + gamma)
            elif k in self.descent:
                new[k] = self.project(k, v - eta * grads[k])
            else:
                new[k] = v + eta * grads[k]
        return new

    def project(self, field: str, value):
        """Feasibility projection for ``descent`` fields (identity here)."""
        return value

    def stage_duals(self, h, y, duals) -> dict[str, jax.Array]:
        """Closed-form re-estimates for ``stage_fields`` from a fresh batch
        (one machine's view; the caller worker-means the results)."""
        return {}

    def metric(self, backend: str = "exact", **kw):
        """Build this objective's reporting metric as a mergeable
        ``repro.metrics.streaming.Metric`` (``backend`` ∈ {exact, sketch};
        sketch kwargs ``bins``/``lo``/``hi`` pass through)."""
        from repro.metrics import streaming  # deferred: metrics finalizes here

        return streaming.make_metric(self.metric_name, backend, **kw)

    @property
    def eval_metric(self):
        raise AttributeError(
            "Objective.eval_metric was removed by the Metric redesign: use "
            "Objective.metric(backend) — a mergeable Metric with init/"
            "update/merge/finalize (repro.metrics.streaming); one-shot "
            "evaluation is metric('exact').compute(scores, labels).")


def _zeros(K: int):
    return jnp.zeros((K,), jnp.float32)


class AUCObjective(Objective):
    """Ying et al. min-max AUC (paper eq. 2): duals (a, b, α) where a/b track
    the class-conditional score means (proximal minimization) and α is the
    concave dual with closed-form stage-end maximizer ``optimal_alpha``."""

    name = "auc"
    prox_refs = ("a", "b")
    stage_fields = ("alpha",)
    metric_name = "auc"

    def __init__(self, p_pos: float = 0.5):
        self.p_pos = p_pos

    def init_duals(self, K: int):
        return {"a": _zeros(K), "b": _zeros(K), "alpha": _zeros(K)}

    def loss(self, h, y, duals):
        return auc_F(h, y, duals["a"], duals["b"], duals["alpha"], self.p_pos)

    def stage_duals(self, h, y, duals):
        return {"alpha": optimal_alpha(h, y)}


class PAUCDROObjective(Objective):
    """One-way partial AUC at FPR ≤ β as a KL-DRO min-max.

    The AUC surrogate's negative-side expectation E⁻[ℓ_j],
    ℓ_j = (h_j − b)² + 2(1+α)h_j, is replaced by its KL-DRO value

        min_{λ ≥ λ_min}  λ·log(1/β) + λ·log E⁻[exp(ℓ_j / λ)]

    — the dual of  max_{q : KL(q‖uniform) ≤ log(1/β)} Σ_j q_j ℓ_j.  The
    gradient through the log-sum-exp reweights negatives by
    q_j ∝ exp(ℓ_j/λ): at small λ only the hardest (top-scoring) negatives
    matter, which is the FPR ≤ β head of the ROC curve; λ → ∞ recovers the
    full-AUC objective.  λ rides the dual state (field ``lam``, projected
    descent at floor ``lam_min``) so the executors, CODASCA variates, and
    payload accounting treat it like any other dual — the dual tree simply
    has four fields instead of three.  a/b/α keep their AUC roles, with α's
    stage-end maximizer computed under the DRO weights.
    """

    name = "pauc_dro"
    prox_refs = ("a", "b")
    descent = ("lam",)
    stage_fields = ("alpha",)
    metric_name = "pauc"

    def __init__(self, p_pos: float = 0.5, beta: float = 0.3,
                 lam_init: float = 1.0, lam_min: float = 0.05):
        self.p_pos = p_pos
        self.beta = beta
        self.lam_init = lam_init
        self.lam_min = lam_min
        self.rho = float(np.log(1.0 / beta))

    def init_duals(self, K: int):
        return {"a": _zeros(K), "b": _zeros(K), "alpha": _zeros(K),
                "lam": jnp.full((K,), self.lam_init, jnp.float32)}

    def _neg_losses(self, h, duals):
        return (h - duals["b"]) ** 2 + 2.0 * (1.0 + duals["alpha"]) * h

    def loss(self, h, y, duals):
        p = self.p_pos
        h = h.astype(jnp.float32)
        pos = y.astype(jnp.float32)
        neg = 1.0 - pos
        n_pos = jnp.sum(pos)
        n_neg = jnp.sum(neg)
        a, alpha = duals["a"], duals["alpha"]
        lam = jnp.maximum(duals["lam"], self.lam_min)
        mean_pos = lambda z: jnp.sum(z * pos) / jnp.maximum(n_pos, _EPS)
        pos_side = ((1.0 - p) * mean_pos((h - a) ** 2)
                    - 2.0 * (1.0 + alpha) * (1.0 - p) * mean_pos(h)
                    - p * (1.0 - p) * alpha * alpha)
        # KL-DRO value of the negative-side losses: λρ + λ·log E⁻[exp(ℓ/λ)].
        # Double-where guard: an all-positive batch (Dirichlet-starved
        # shards hit this) would make logsumexp(b=0) a NaN whose *gradient*
        # leaks through a single jnp.where — so the inner computation runs
        # on a safe uniform mask and the outer where zeroes the value.
        has_neg = n_neg > 0
        neg_safe = jnp.where(has_neg, neg, jnp.ones_like(neg))
        lse = jax.scipy.special.logsumexp(self._neg_losses(h, duals) / lam,
                                          b=neg_safe)
        dro = lam * (self.rho + lse - jnp.log(jnp.sum(neg_safe)))
        return pos_side + jnp.where(has_neg, p * dro, 0.0)

    def project(self, field: str, value):
        return jnp.maximum(value, self.lam_min)

    def stage_duals(self, h, y, duals):
        """α* = Ê_q[h | y=-1] − E[h | y=1] under the current DRO weights
        q_j ∝ exp(ℓ_j/λ) — ``optimal_alpha`` with the negative expectation
        tilted toward the hard negatives."""
        h = h.astype(jnp.float32)
        pos = y.astype(jnp.float32)
        neg = 1.0 - pos
        has_neg = jnp.sum(neg) > 0
        neg_safe = jnp.where(has_neg, neg, jnp.ones_like(neg))
        lam = jnp.maximum(duals["lam"], self.lam_min)
        logits = self._neg_losses(h, duals) / lam
        logits = jnp.where(neg_safe > 0.5, logits, -jnp.inf)
        q = jax.nn.softmax(logits)
        mean_neg = jnp.where(has_neg, jnp.sum(q * h), 0.0)
        mean_pos = jnp.sum(h * pos) / jnp.maximum(jnp.sum(pos), _EPS)
        return {"alpha": mean_neg - mean_pos}

    def metric(self, backend: str = "exact", **kw):
        kw.setdefault("beta", self.beta)
        from repro.metrics import streaming

        return streaming.make_metric("pauc", backend, **kw)


class BCEObjective(Objective):
    """Dual-free binary cross-entropy — the introduction's "standard loss
    minimization" strawman, routed through the same seam: the dual tree is
    empty, so the executors run pure distributed SGD with zero dual payload
    (``baselines.bce_step`` shares this loss instead of its own closure).

    The scores ``h`` every executor feeds this are the *unbounded*
    ``score_head`` logits, so the loss is logit-space BCE
    (``-[y·log σ(h) + (1−y)·log σ(−h)]`` via the stable ``log_sigmoid``).
    The old form clipped ``h`` into (1e-6, 1−1e-6) and took logs — treating
    a logit as a probability — so any score outside (0, 1) saturated the
    clip and its gradient vanished exactly; pinned against the explicit
    sigmoid+log oracle in tests/test_objective.py."""

    name = "bce"
    metric_name = "auc"

    def __init__(self, p_pos: float = 0.5):
        self.p_pos = p_pos  # unused by the loss; kept for a uniform ctor

    def init_duals(self, K: int):
        return {}

    def loss(self, h, y, duals):
        h = h.astype(jnp.float32)
        y = y.astype(jnp.float32)
        return -jnp.mean(y * jax.nn.log_sigmoid(h)
                         + (1.0 - y) * jax.nn.log_sigmoid(-h))


REGISTRY = {"auc": AUCObjective, "pauc_dro": PAUCDROObjective,
            "bce": BCEObjective}


def names() -> tuple[str, ...]:
    return tuple(REGISTRY)


def for_config(ccfg) -> Objective:
    """Build the configured objective from a ``CoDAConfig``."""
    name = getattr(ccfg, "objective", "auc")
    if name == "pauc_dro":
        return PAUCDROObjective(p_pos=ccfg.p_pos, beta=ccfg.pauc_beta)
    return REGISTRY[name](p_pos=ccfg.p_pos)
