"""The min-max AUC objective (Ying et al. 2016 reformulation; paper eq. 2).

``auc_F`` is a differentiable fused primitive: forward and *all* partials
come from one pass over the scores (``kernels.ops.auc_loss`` — Pallas on TPU,
closed-form jnp elsewhere), wired into autodiff with ``jax.custom_vjp``.  The
closed-form partials are exactly the expressions in Appendix B (eq. 34) of
the paper restricted to the scalar head:

    ∂F/∂h = 2(1-p)(h-a)·1⁺ + 2p(h-b)·1⁻ + 2(1+α)(p·1⁻ − (1-p)·1⁺)
    ∂F/∂a = −2(1-p)(h-a)·1⁺        ∂F/∂b = −2p(h-b)·1⁻
    ∂F/∂α = 2(p·h·1⁻ − (1-p)·h·1⁺) − 2p(1-p)α
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


@jax.custom_vjp
def auc_F(h, y, a, b, alpha, p):
    """Mean of F(w,a,b,α;z) over the batch.  h: [T] scores, y: [T] ∈ {0,1}."""
    loss, *_ = kops.auc_loss(h, y, a, b, alpha, p)
    return loss


def _fwd(h, y, a, b, alpha, p):
    loss, dh, da, db, dalpha = kops.auc_loss(h, y, a, b, alpha, p)
    return loss, (dh.astype(h.dtype), da, db, dalpha)


def _bwd(res, ct):
    dh, da, db, dalpha = res
    return (ct * dh, None, ct * da, ct * db, ct * dalpha, None)


auc_F.defvjp(_fwd, _bwd)


def optimal_alpha(h, y, eps: float = 1e-12):
    """Closed-form maximizer α*(v) = E[h|y=-1] − E[h|y=1] (paper eq. 8),
    estimated on a batch — this is Algorithm 1 lines 4–7 for one machine."""
    h = h.astype(jnp.float32)
    pos = y.astype(jnp.float32)
    neg = 1.0 - pos
    mean_neg = jnp.sum(h * neg) / jnp.maximum(jnp.sum(neg), eps)
    mean_pos = jnp.sum(h * pos) / jnp.maximum(jnp.sum(pos), eps)
    return mean_neg - mean_pos


def roc_auc(scores, labels):
    """Exact (tie-aware) empirical AUC via rank statistics."""
    s = scores.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    order = jnp.argsort(s)
    ss = s[order]
    ranks1 = jnp.arange(1, s.shape[0] + 1, dtype=jnp.float32)
    # average ranks over ties
    first = jnp.searchsorted(ss, ss, side="left").astype(jnp.float32) + 1
    last = jnp.searchsorted(ss, ss, side="right").astype(jnp.float32)
    avg_rank_sorted = 0.5 * (first + last)
    ranks = jnp.zeros_like(ranks1).at[order].set(avg_rank_sorted)
    n_pos = jnp.sum(y)
    n_neg = jnp.sum(1.0 - y)
    sum_pos_ranks = jnp.sum(ranks * y)
    return (sum_pos_ranks - n_pos * (n_pos + 1) / 2) / jnp.maximum(n_pos * n_neg, 1e-12)
