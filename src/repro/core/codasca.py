"""CODASCA — CoDA with stochastic controlled averaging for heterogeneous
data (Yuan et al., "Federated Deep AUC Maximization for Heterogeneous Data
with a Constant Communication Complexity", ICML 2021).

CoDA's analysis assumes every worker draws from the same distribution.  The
batch setting already violates that: a fixed dataset is *partitioned*, so
machine k's empirical distribution P_k drifts from P — and over the I
communication-free local steps each worker walks toward its own shard's
optimum, biasing the primal-dual updates (the per-worker loss spread that
``ShardedExecutor.window_step`` surfaces is exactly this signal).

CODASCA cancels the drift SCAFFOLD-style with per-worker control variates.
Every primal/dual variable v gets a worker-local variate c_k(v) and a
global variate c(v); each of the I local steps applies the corrected
gradient

    g̃ = g + (c − c_k)

so the *expected* local direction matches the global one even when the
shards differ.  At the window end c_k is refreshed to the worker's mean
raw gradient over the window (1/I · Σ_t g_t) and c to the worker-mean of
the fresh c_k — and because the refresh is just one more mean over the
worker axis, it rides the SAME bucketed all-reduce as the model averaging:

  * communication stays ONE all-reduce per window (``comm_rounds``
    unchanged vs CoDA);
  * the payload doubles to ``2 × coda.model_bytes(state)`` — state tensors
    + control variates in one concatenated bucket, asserted against the
    compiled HLO in tests/test_codasca.py via
    ``analysis.hlo.verify_window_payload``.

State layout (on top of ``coda.init_state``): ``cv_params``/``cv_duals``
are worker k's variates (leading [K] axis, *never* shipped except through
their mean) and ``cg_params``/``cg_duals`` the global variates (replicated
over the [K] axis so every sharding rule stays uniform).  The variate trees
mirror the objective's ``params``/``duals`` trees exactly — whatever dual
fields the configured objective declares (core/objective.py) get variates,
with no field names hard-coded anywhere below.  All start at zero, so the
first window — and, with homogeneous per-worker batches, every window — is
bit-for-bit a CoDA window: the correction is computed as ``g + (c − c_k)``,
and ``c − c_k`` is an exact floating-point zero whenever the two variates
are equal.  That is the α = ∞ equivalence tier-1 checks.

Both executors run the one ``run_window`` below: the vmap oracle passes
``wa=()`` (plain axis-0 means), the shard_map executor its worker mesh
axes — the two paths share every arithmetic op by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import bucketing, coda


def extend_state(state: coda.CoDAState) -> coda.CoDAState:
    """Add zero control variates to a CoDA state (all fields get their own
    buffers — the jit-once executors donate the state)."""
    zt = lambda t: jax.tree_util.tree_map(jnp.zeros_like, t)
    new = dict(state)
    new["cv_params"], new["cg_params"] = zt(state["params"]), zt(state["params"])
    new["cv_duals"], new["cg_duals"] = zt(state["duals"]), zt(state["duals"])
    return new


def local_step(mcfg: ModelConfig, ccfg: coda.CoDAConfig, state, batch, eta):
    """One control-variate-corrected primal-dual update on every worker.

    Returns (new_state, per_worker_losses [K], raw_grads) — the *raw*
    (uncorrected) gradients feed the window's variate refresh.
    """
    if "sk_new" in state:
        losses, grads, hs = coda.grad_step_scores(mcfg, ccfg, state, batch)
    else:
        losses, grads = coda.grad_step(mcfg, ccfg, state, batch)
        hs = None
    gp, gd = grads
    # g + (c − c_k): the difference is computed FIRST so equal variates
    # contribute an exact fp zero (the homogeneous-data equivalence).
    corr = lambda g, c, ck: g + (c - ck)
    gp_c = jax.tree_util.tree_map(corr, gp, state["cg_params"],
                                  state["cv_params"])
    gd_c = jax.tree_util.tree_map(corr, gd, state["cg_duals"],
                                  state["cv_duals"])
    new = coda.apply_grads(ccfg, state, (gp_c, gd_c), eta)
    if hs is not None:
        new["sk_new"] = coda.sketch_update(ccfg, state["sk_new"], hs,
                                           batch["labels"])
    return new, losses, grads


def run_window(mcfg: ModelConfig, ccfg: coda.CoDAConfig, state, window_batch,
               eta, *, wa=(), communicate: bool = True, ring=None,
               faults=None):
    """I corrected local steps + the single combined all-reduce.

    ``wa``: worker mesh axes ((),) for the vmap oracle.  ``ring``: a
    ``bucketing.RingSpec`` to lower the combined averaging as chunked
    ppermute rings instead of the blocking pmean (the overlapped path).
    ``faults``: per-window fault vectors (core/faults.py) switching the
    combined collective to the masked form — state rows merge over the
    participation weights, the variates refresh over the participants only
    (``cg == participant mean``, absent workers keep their old c_k; see
    ``bucketing.masked_average_and_refresh``).
    Returns (new_state, losses [I, K_loc]).

    The raw-gradient accumulator feeding the variate refresh runs in fp32
    regardless of ``param_dtype``: a bf16 accumulator loses a bit of the
    window mean per doubling of I (the drift the bf16 regression test in
    tests/test_codasca.py pins down), and the variates are exactly the
    quantity that must stay an unbiased window mean.  The refresh casts
    back to the wire dtype so c and c_k keep sharing one bucket layout.
    """
    from repro import flags

    def step(carry, b):
        st, acc = carry
        st, losses, (gp, gd) = local_step(mcfg, ccfg, st, b, eta)
        gd_tree = {"params": gp, "duals": gd}
        acc = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32), acc, gd_tree)
        return (st, acc), losses

    f32z = lambda t: jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, jnp.float32), t)
    acc0 = {"params": f32z(state["params"]), "duals": f32z(state["duals"])}
    start_params = state["params"]
    (state, acc), losses = jax.lax.scan(step, (state, acc0), window_batch,
                                        unroll=flags.scan_unroll())
    if communicate:
        I = jax.tree_util.tree_leaves(window_batch)[0].shape[0]
        wire = {"params": state["params"], "duals": state["duals"]}
        cv_new = jax.tree_util.tree_map(
            lambda g, w: (g / I).astype(w.dtype), acc, wire)
        if faults is not None:
            state = bucketing.masked_average_and_refresh(
                state, cv_new, faults, wa, ccfg.avg_compress or None,
                ring=ring)
        else:
            state = bucketing.average_and_refresh(state, cv_new, wa,
                                                  ccfg.avg_compress or None,
                                                  ring=ring,
                                                  n_workers=ccfg.n_workers)
        if ccfg.server_momentum:  # rejected with faults at config time
            state = coda.server_momentum_step(state, start_params,
                                              ccfg.server_momentum)
    return state, losses


def window_step(mcfg: ModelConfig, ccfg: coda.CoDAConfig, state, window_batch,
                eta, *, communicate: bool = True, faults=None):
    """Vmap-oracle window: same surface as ``coda.window_step``."""
    state, losses = run_window(mcfg, ccfg, state, window_batch, eta,
                               wa=(), communicate=communicate, faults=faults)
    return state, jnp.mean(losses, axis=1)
