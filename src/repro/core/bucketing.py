"""Bucketed cross-worker averaging shared by the executors.

Both the vmap oracle (``wa=()``: plain axis-0 means, nothing crosses a
wire) and the shard_map executor (``wa=("data",)`` etc.: one ``lax.pmean``
per dtype bucket) run *these* functions, so the two paths cannot drift:
the oracle's arithmetic is the sharded executor's arithmetic with the
collective removed.

Two payload layouts:

  * ``average_state`` — plain CoDA: the state tensors (every ``params``
    leaf + every leaf of the objective's ``duals`` tree) form one
    concatenated bucket per dtype; fp32 default = exactly one all-reduce
    of ``coda.model_bytes(state)`` operand bytes.  The layout is derived
    from the tree structure (``_state_mats``), never from field names, so
    any registered objective's dual layout rides the same machinery.
  * ``average_and_refresh`` — CODASCA: the freshly computed per-worker
    control variates ride the SAME bucket as the state tensors, so the
    global control variate c = mean_k c_k costs zero extra rounds — the
    window still lowers to exactly ONE all-reduce, now of
    ``2 × model_bytes`` (state + control payload, HLO-asserted in
    tests/test_codasca.py).

``compress="int8"`` swaps the fp32 pmean for an s8-payload + fp32-scale
all-gather pair (see ``coda.int8_quantize``).

Overlapped (ring) averaging
---------------------------
``ring=RingSpec(...)`` lowers the same per-dtype-bucket mean as C
independent reduce-scatter / all-gather rings built from ``lax.ppermute``
hops instead of one blocking ``lax.pmean``.  The mean is bit-for-the-same-
tolerance identical (sum over the ring, divide by the ring size); what
changes is the *schedule*: each chunk's 2·(R−1) hops form their own
dependency chain, so when the averaging sits inside a fused two-window
step (core/coda_sharded.window_pair_fn) XLA's async collective-permute
scheduling can hide the wire time of late chunks under the next window's
compute on early chunks.  Small buckets (fewer than R elements per chunk)
collapse to one chunk so the hop count stays proportional to real payload.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class RingSpec:
    """How to lower a cross-worker mean as ppermute rings.

    ``axis``: the ONE mesh axis the ring runs over (multi-axis worker
    partitions are rejected by the executor — a ring needs a total order).
    ``size``: number of ring participants (the axis extent).
    ``chunks``: C, how many independent ring chains each dtype bucket is
    split into (more chunks = finer overlap granularity, more hops).
    """
    axis: str
    size: int
    chunks: int

    def __post_init__(self):
        if self.size < 1 or self.chunks < 1:
            raise ValueError(f"bad RingSpec {self}")


def _n_chunks(n: int, ring: RingSpec) -> int:
    """Chunks actually used for an n-element bucket: each chunk must hold at
    least one element per ring shard, else it degenerates to padding."""
    return max(1, min(ring.chunks, n // max(ring.size, 1) or 1))


def _chunk_offsets(n: int, c: int):
    """c+1 split points tiling [0, n) into c chunks whose sizes differ by at
    most one (the first n % c chunks get the extra element)."""
    base, rem = divmod(n, c)
    offs = [0]
    for i in range(c):
        offs.append(offs[-1] + base + (1 if i < rem else 0))
    return offs


def ring_chain_count(sizes: Dict, ring: RingSpec) -> int:
    """Independent ppermute chains one ring-averaging forms: one per chunk
    per dtype bucket (small buckets collapse to a single chunk)."""
    if ring.size == 1:
        return 0
    return sum(_n_chunks(n, ring) for n in sizes.values())


def ring_hop_count(sizes: Dict, ring: RingSpec) -> int:
    """collective-permute ops one ring-averaging emits: per dtype bucket,
    C chains of 2·(R−1) hops (reduce-scatter + all-gather)."""
    return ring_chain_count(sizes, ring) * 2 * (ring.size - 1)


def bucket_sizes(mats) -> Dict:
    """Element count per dtype bucket (the ring/pmean payload layout)."""
    out: Dict = {}
    for m in mats:
        d = jnp.dtype(m.dtype)
        out[d] = out.get(d, 0) + m.shape[1]
    return out


def _ring_chunk_sum(chunk, ring: RingSpec):
    """Sum of a [m] chunk over the ring: reduce-scatter (R−1 ppermute
    hops, each shard ends fully summed on one device) then all-gather
    (R−1 more hops).  Returns the [m] sum (``_ring_chunk_mean`` divides
    by R; the masked path divides by the on-wire weight sum instead)."""
    R, axis = ring.size, ring.axis
    m = chunk.shape[0]
    s = -(-m // R)                       # ring shard length (padded)
    buf = jnp.zeros((R * s,), chunk.dtype).at[:m].set(chunk)
    shards = buf.reshape(R, s)
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % R) for i in range(R)]

    # reduce-scatter: at hop t device i forwards its partial of shard
    # (i−t+1) and folds its own contribution into the one it receives;
    # after R−1 hops device i holds the full sum of shard (i−R+2) mod R.
    send = jnp.take(shards, (idx + 1) % R, axis=0)
    for t in range(R - 1):
        recvd = jax.lax.ppermute(send, axis, perm)
        send = jnp.take(shards, (idx - t) % R, axis=0) + recvd
    own = (idx - (R - 2)) % R

    # all-gather: circulate the completed shards around the same ring.
    out = jnp.zeros((R, s), chunk.dtype)
    out = jax.lax.dynamic_update_slice(out, send[None], (own, 0))
    cur = send
    for t in range(R - 1):
        cur = jax.lax.ppermute(cur, axis, perm)
        out = jax.lax.dynamic_update_slice(out, cur[None],
                                           ((own - 1 - t) % R, 0))
    return out.reshape(-1)[:m]


def _ring_chunk_mean(chunk, ring: RingSpec):
    """Mean of a [m] chunk over the ring (the unmasked overlapped path)."""
    return _ring_chunk_sum(chunk, ring) / ring.size


def _ring_buckets(mats, ring: RingSpec, *, mean: bool):
    """Per-dtype bucket reduction lowered as chunked ppermute rings: local
    reduce over the K_loc rows, then C independent reduce-scatter/all-gather
    chains over the ring axis.  ``mean=True`` is the classical averaging;
    ``mean=False`` carries the raw sum (the masked path divides by the
    on-wire weight sum instead of the ring size)."""
    local_red = jnp.mean if mean else jnp.sum
    chunk_red = _ring_chunk_mean if mean else _ring_chunk_sum
    by_dtype = {}
    for i, m in enumerate(mats):
        by_dtype.setdefault(jnp.dtype(m.dtype), []).append(i)
    out = [None] * len(mats)
    for idxs in by_dtype.values():
        buf = jnp.concatenate([mats[i] for i in idxs], axis=1)
        local = local_red(buf, axis=0)         # [N] this shard's partial
        n = local.shape[0]
        if ring.size == 1:
            red = local                        # degenerate: no wire
        else:
            # near-even split (sizes differ by ≤ 1, never 0): a ceil-based
            # split could leave empty trailing chunks whose zero-byte
            # permute chains XLA may DCE, breaking ring_hop_count
            offs = _chunk_offsets(n, _n_chunks(n, ring))
            red = jnp.concatenate([
                chunk_red(local[lo:hi], ring)
                for lo, hi in zip(offs[:-1], offs[1:])])
        offs = np.cumsum([0] + [mats[i].shape[1] for i in idxs])
        for j, i in enumerate(idxs):
            out[i] = red[offs[j]:offs[j + 1]]
    return out


def ring_mean_buckets(mats, ring: RingSpec):
    """``pmean_buckets`` semantics lowered as chunked ppermute rings."""
    return _ring_buckets(mats, ring, mean=True)


def ring_sum_buckets(mats, ring: RingSpec):
    """``psum_buckets`` semantics lowered as chunked ppermute rings (the
    masked overlapped path: rows arrive pre-scaled, the weight lane rides
    the f32 bucket)."""
    return _ring_buckets(mats, ring, mean=False)


def _reduce_buckets(mats, wa, *, mean: bool):
    """Reduce the [K_loc, n_i] matrices over the global worker axis,
    shipping one concatenated bucket per dtype (one all-reduce each;
    exactly one for the default all-fp32 state).  Returns [n_i] vectors."""
    local_red = jnp.mean if mean else jnp.sum
    wire_red = jax.lax.pmean if mean else jax.lax.psum
    by_dtype = {}
    for i, m in enumerate(mats):
        by_dtype.setdefault(jnp.dtype(m.dtype), []).append(i)
    out = [None] * len(mats)
    for idxs in by_dtype.values():
        buf = jnp.concatenate([mats[i] for i in idxs], axis=1)
        red = local_red(buf, axis=0)
        if wa:
            red = wire_red(red, wa)
        offs = np.cumsum([0] + [mats[i].shape[1] for i in idxs])
        for j, i in enumerate(idxs):
            out[i] = red[offs[j]:offs[j + 1]]
    return out


def pmean_buckets(mats, wa):
    """Per-dtype bucketed cross-worker MEAN (the unmasked window layout)."""
    return _reduce_buckets(mats, wa, mean=True)


def psum_buckets(mats, wa):
    """Per-dtype bucketed cross-worker SUM — the masked-window collective.

    An exact masked mean cannot be a rescaled pmean (mean-then-rescale
    rounds twice); instead every row is pre-scaled by its worker's weight,
    the buckets are SUMMED, and a weight lane riding the f32 bucket carries
    Σu so the division happens once, after the wire.  Same op count as
    ``pmean_buckets``: still exactly one all-reduce per dtype bucket."""
    return _reduce_buckets(mats, wa, mean=False)


def int8_average(mats, wa):
    """Compressed averaging: per-(worker, tensor) max-abs fp32 scales, int8
    payload.  Only the s8 bucket and the fp32 scales cross the wire (one
    all-gather each); dequantize + mean happen on every shard."""
    from repro.core import coda

    qs, scales = [], []
    for m in mats:
        q, scale = coda.int8_quantize(m.astype(jnp.float32), (1,))
        qs.append(q)
        scales.append(scale)
    qbuf = jnp.concatenate(qs, axis=1)       # [K_loc, N] int8 payload
    sbuf = jnp.concatenate(scales, axis=1)   # [K_loc, L] fp32 scales
    if wa:
        qbuf = jax.lax.all_gather(qbuf, wa, axis=0, tiled=True)
        sbuf = jax.lax.all_gather(sbuf, wa, axis=0, tiled=True)
    out, off = [], 0
    for i, m in enumerate(mats):
        n = m.shape[1]
        deq = qbuf[:, off:off + n].astype(jnp.float32) * sbuf[:, i:i + 1]
        out.append(jnp.mean(deq, axis=0).astype(m.dtype))
        off += n
    return out


def _state_mats(state):
    """The wire payload as a flat list of [K_loc, n_i] matrices + treedef.

    ``state`` is anything with a ``params`` tree and a ``duals`` dict-tree
    (the full CoDA state, or CODASCA's ``cv_new`` refresh dict) — the leaf
    order is jax's dict flattening order (keys sorted: dual leaves before
    params leaves), derived purely from the tree structure so every
    objective's dual layout ships the same way.  ``coda._payload_leaves``
    mirrors this exact flattening for the byte accounting."""
    flat, tdef = jax.tree_util.tree_flatten(
        {"params": state["params"], "duals": state["duals"]})
    kloc = flat[0].shape[0]
    mats = [l.reshape(kloc, -1) for l in flat]
    return mats, (flat, tdef), kloc


def _unmats(meta, kloc, means, *, broadcast=True):
    """Means back into a {"params": tree, "duals": dict} pair."""
    flat, tdef = meta
    outs = []
    for leaf, mean in zip(flat, means):
        trail = leaf.shape[1:]
        r = mean.reshape(trail)
        if broadcast:
            r = jnp.broadcast_to(r, (kloc,) + trail)
        outs.append(r.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(tdef, outs)
    return tree["params"], tree["duals"]


def _sketch_mats(state, n_workers):
    """The streaming-eval sketch deltas (``sk_new``) as wire rows riding the
    fp32 bucket: each [K_loc, B] count leaf is PRE-SCALED by the global
    worker count K, so the collective's *mean* is the exact global *sum*:
    every partial numerator is an exact integer-valued fp32 and every
    division (by K_loc locally, by the ring/pmean extent on the wire) has
    an exactly-representable integer quotient — correctly-rounded fp32
    division returns it exactly.  Returns ([] , None) when the sketch is
    off."""
    if "sk_new" not in state:
        return [], None
    if not n_workers:
        raise ValueError("averaging a state with a streaming-eval sketch "
                         "needs n_workers (the pre-scale that turns the "
                         "wire mean into the exact count sum)")
    flat, tdef = jax.tree_util.tree_flatten(state["sk_new"])
    kloc = flat[0].shape[0]
    mats = [(l.astype(jnp.float32) * np.float32(n_workers)).reshape(kloc, -1)
            for l in flat]
    return mats, (flat, tdef)


def _apply_sketch_sums(new, smeta, sums):
    """Fold the collective's exact delta sums into the replicated
    accumulator and reset the deltas (the wire twin of
    ``coda.merge_sketch``)."""
    flat, tdef = smeta
    delta = jax.tree_util.tree_unflatten(
        tdef, [s.reshape(l.shape[1:]) for s, l in zip(sums, flat)])
    new["sk_acc"] = jax.tree_util.tree_map(
        lambda a, d: a + jnp.broadcast_to(d, a.shape), new["sk_acc"], delta)
    if "sk_loc" in new:
        # per-shard readout (coda.merge_sketch's wire twin): each worker
        # folds its OWN delta into its local history — no collective
        new["sk_loc"] = jax.tree_util.tree_map(
            lambda c, d: c + d, new["sk_loc"], new["sk_new"])
    new["sk_new"] = jax.tree_util.tree_map(jnp.zeros_like, new["sk_new"])
    return new


def average_state(state, wa, compress: str | None, *,
                  ring: RingSpec | None = None,
                  n_workers: int | None = None):
    """``coda.average`` semantics on a local worker shard: mean over the
    K_loc local workers, then over the worker mesh axes.  ``ring`` swaps
    the blocking pmean for the chunked ppermute rings (fp32 buckets only —
    int8 + ring is rejected at config time).  A streaming-eval sketch in
    the state (``sk_new``/``sk_acc``) rides the same fp32 bucket — still
    one all-reduce — and needs ``n_workers`` (see ``_sketch_mats``)."""
    mats, meta, kloc = _state_mats(state)
    smats, smeta = _sketch_mats(state, n_workers)
    if ring is not None and compress:
        raise ValueError("ring averaging does not support compressed buckets")
    if compress == "int8":
        if smats:  # unreachable via CoDAConfig; guard direct callers
            raise ValueError("the streaming-eval sketch cannot ride int8 "
                             "compressed buckets")
        means = int8_average(mats, wa)
    else:
        means = ring_mean_buckets(mats + smats, ring) if ring is not None \
            else pmean_buckets(mats + smats, wa)
    tree, duals = _unmats(meta, kloc, means[:len(mats)])
    new = dict(state)
    new["params"] = tree
    new["duals"] = duals
    if smeta is not None:
        new = _apply_sketch_sums(new, smeta, means[len(mats):])
    return new


def average_and_refresh(state, cv_new, wa, compress: str | None, *,
                        ring: RingSpec | None = None,
                        n_workers: int | None = None):
    """CODASCA window end: average the state tensors AND the per-worker
    control variates in one bucket.  The state mean is broadcast back (all
    workers restart from the synced iterate), the control mean becomes the
    new global variate ``cg_*``, and each worker keeps its OWN ``cv_new``
    as ``cv_*`` — c_k never crosses the wire except through its mean.

    ``cv_new``: dict with the same layout as the state's averaged slice
    ({"params": tree, "duals": dict}).

    Under ``compress="int8"`` the *dequantized* variates are stored as
    ``cv_*`` — c and c_k must share the quantizer, or the corrections
    ``c − c_k`` pick up a common bias of one quantization step per window
    and the K=1 / homogeneous CODASCA ≡ CoDA equivalences break.
    """
    mats, meta, kloc = _state_mats(state)
    cmats, cmeta, _ = _state_mats(cv_new)
    smats, smeta = _sketch_mats(state, n_workers)
    if ring is not None:
        if compress:
            raise ValueError("ring averaging does not support compressed "
                             "buckets")
        means = ring_mean_buckets(mats + cmats + smats, ring)
    elif compress == "int8":
        from repro.core import coda

        if smats:  # unreachable via CoDAConfig; guard direct callers
            raise ValueError("the streaming-eval sketch cannot ride int8 "
                             "compressed buckets")
        means = int8_average(mats + cmats, wa)
        # each worker re-applies the wire quantizer to its OWN variate rows
        # (locally — nothing extra crosses the wire), so cg == mean_k cv_k
        # holds exactly under quantization
        stored = []
        for m in cmats:
            q, s = coda.int8_quantize(m.astype(jnp.float32), (1,))
            stored.append((q.astype(jnp.float32) * s).astype(m.dtype))
        cmats = stored
    else:
        means = pmean_buckets(mats + cmats + smats, wa)
    n, nc = len(mats), len(cmats)
    tree, duals = _unmats(meta, kloc, means[:n])
    ctree, cduals = _unmats(cmeta, kloc, means[n:n + nc])
    new = dict(state)
    new["params"] = tree
    new["duals"] = duals
    if smeta is not None:
        new = _apply_sketch_sums(new, smeta, means[n + nc:])
    new["cg_params"], new["cg_duals"] = ctree, cduals
    cflat, ctdef = cmeta
    stored_flat = [m.reshape(l.shape) for m, l in zip(cmats, cflat)]
    stored_tree = jax.tree_util.tree_unflatten(ctdef, stored_flat)
    new["cv_params"] = stored_tree["params"]
    new["cv_duals"] = stored_tree["duals"]
    return new


# --------------------------------------------------------------------------
# masked (partial-participation) averaging — core/faults.py feeds the masks
# --------------------------------------------------------------------------
# The fault-tolerant window replaces the bucketed MEAN with an exact masked
# weighted mean over the participants:
#
#     merged = Σ_k u_k · x_k / Σ_k u_k
#
# computed as ONE bucketed all-reduce per dtype, exactly like the unmasked
# layout: every row is pre-scaled by its worker's weight u_k BEFORE the
# collective (absent workers contribute exact zeros — u ∈ {0, 1} and
# power-of-two staleness discounts round-trip every float dtype exactly),
# the buckets are SUMMED (psum — a rescaled pmean would round twice), and a
# tiny f32 *weight lane* rides the f32 bucket so Σu crosses the wire inside
# the same collective: the masked payload is the unmasked payload + 4 bytes
# (+ 8 for CODASCA, which also ships the binary participant count Σm for
# the variate refresh).  After the wire, ``resync`` selects per worker:
# participants and re-syncing workers adopt the merged state, mid-straggle
# workers (resync 0) keep their own iterate.


def _masked_sketch_mats(state, m):
    """The streaming-eval sketch deltas under the masked SUM collective:
    rows pre-scaled by the binary participation mask only (no mean
    pre-scale — the wire op is already a sum), so participants' exact
    integer-valued fp32 counts fold in and absent workers' deltas stay
    local, merging at their next participating window."""
    if "sk_new" not in state:
        return [], None
    flat, tdef = jax.tree_util.tree_flatten(state["sk_new"])
    kloc = flat[0].shape[0]
    mats = [l.astype(jnp.float32).reshape(kloc, -1) * m[:, None]
            for l in flat]
    return mats, (flat, tdef)


def _apply_masked_sketch_sums(new, smeta, sums, m):
    """Fold the participants' delta sums into the replicated accumulator;
    reset only the participants' deltas (binary mask — the multiply is
    exact)."""
    flat, tdef = smeta
    delta = jax.tree_util.tree_unflatten(
        tdef, [s.reshape(l.shape[1:]) for s, l in zip(sums, flat)])
    new["sk_acc"] = jax.tree_util.tree_map(
        lambda a, d: a + jnp.broadcast_to(d, a.shape), new["sk_acc"], delta)
    if "sk_loc" in new:
        # fold exactly what merged globally: participants' deltas only
        # (binary mask — exact), so Σ_k sk_loc[k] tracks sk_acc's history
        new["sk_loc"] = jax.tree_util.tree_map(
            lambda c, l: c + l * m.reshape((l.shape[0],)
                                           + (1,) * (l.ndim - 1)),
            new["sk_loc"], new["sk_new"])
    keep = 1.0 - m
    new["sk_new"] = jax.tree_util.tree_map(
        lambda l: l * keep.reshape((l.shape[0],) + (1,) * (l.ndim - 1)),
        new["sk_new"])
    return new


def _select_rows(meta, kloc, merged, take):
    """The post-collective state update: rows with ``take > 0``
    (participants + re-syncing workers) adopt the merged value, rows with
    ``take == 0`` (mid-straggle workers that never saw the broadcast) keep
    their own iterate."""
    flat, tdef = meta
    outs = []
    for leaf, v in zip(flat, merged):
        mg = jnp.broadcast_to(
            v.astype(leaf.dtype).reshape(leaf.shape[1:]), leaf.shape)
        t = take.reshape((kloc,) + (1,) * (leaf.ndim - 1))
        outs.append(jnp.where(t > 0, mg, leaf))
    tree = jax.tree_util.tree_unflatten(tdef, outs)
    return tree["params"], tree["duals"]


def masked_int8_average(mats, lane_idx, lanes, wa):
    """``int8_average`` under partial participation: the s8 payload is the
    same per-worker quantized rows (weights never touch the int8 bucket —
    scaling quantized rows would corrupt the shared quantizer), and the f32
    weight lanes are appended to the *scales* gather, so after the same
    all-gather pair every shard holds the full [K] weight vectors and the
    weighted dequantized mean is computed redundantly everywhere.

    ``lanes``: [K_loc, n_lanes] f32 weight columns; ``lane_idx[i]`` names
    which lane weights tensor i (state rows ride the participation weight
    u, CODASCA variate rows the binary mask m).  Wire cost over the
    unmasked pair: 4·n_lanes extra f32 bytes per worker."""
    from repro.core import coda

    qs, scales = [], []
    for m in mats:
        q, scale = coda.int8_quantize(m.astype(jnp.float32), (1,))
        qs.append(q)
        scales.append(scale)
    qbuf = jnp.concatenate(qs, axis=1)                # [K_loc, N] int8
    sbuf = jnp.concatenate(scales + [lanes], axis=1)  # [K_loc, L + n_lanes]
    if wa:
        qbuf = jax.lax.all_gather(qbuf, wa, axis=0, tiled=True)
        sbuf = jax.lax.all_gather(sbuf, wa, axis=0, tiled=True)
    L = len(mats)
    lanebuf = sbuf[:, L:]                             # [K, n_lanes]
    totals = jnp.maximum(jnp.sum(lanebuf, axis=0), 1.0)
    out, off = [], 0
    for i, m in enumerate(mats):
        n = m.shape[1]
        deq = qbuf[:, off:off + n].astype(jnp.float32) * sbuf[:, i:i + 1]
        w = lanebuf[:, lane_idx[i]:lane_idx[i] + 1]
        out.append((jnp.sum(deq * w, axis=0) / totals[lane_idx[i]])
                   .astype(m.dtype))
        off += n
    return out


def masked_average_state(state, faults, wa, compress: str | None, *,
                         ring: RingSpec | None = None):
    """``average_state`` under partial participation: the exact
    u-weighted mean over the participants, still one collective per dtype
    bucket (psum / ring-sum / int8 gather pair), with the weight lane
    riding the f32 bucket.  ``faults``: {"weights": [K_loc] f32,
    "resync": [K_loc] f32} from ``core.faults.FaultPlan.window``."""
    u = faults["weights"].astype(jnp.float32)
    r = faults["resync"].astype(jnp.float32)
    m = (u > 0).astype(jnp.float32)
    mats, meta, kloc = _state_mats(state)
    smats, smeta = _masked_sketch_mats(state, m)
    n = len(mats)
    if ring is not None and compress:
        raise ValueError("ring averaging does not support compressed buckets")
    if compress == "int8":
        if smats:  # unreachable via CoDAConfig; guard direct callers
            raise ValueError("the streaming-eval sketch cannot ride int8 "
                             "compressed buckets")
        means = masked_int8_average(mats, [0] * n, u[:, None], wa)
        ssums = []
    else:
        scaled = [mt * u.astype(mt.dtype)[:, None] for mt in mats]
        lane = u[:, None]            # Σu crosses inside the f32 bucket
        allm = scaled + [lane] + smats
        sums = ring_sum_buckets(allm, ring) if ring is not None \
            else psum_buckets(allm, wa)
        W = jnp.maximum(sums[n][0], 1.0)
        means = [s.astype(jnp.float32) / W for s in sums[:n]]
        ssums = sums[n + 1:]
    take = jnp.maximum(m, r)
    params, duals = _select_rows(meta, kloc, means, take)
    new = dict(state)
    new["params"] = params
    new["duals"] = duals
    if smeta is not None:
        new = _apply_masked_sketch_sums(new, smeta, ssums, m)
    return new


def masked_average_and_refresh(state, cv_new, faults, wa,
                               compress: str | None, *,
                               ring: RingSpec | None = None):
    """``average_and_refresh`` under partial participation (the CODASCA
    bookkeeping of Yuan et al. 2021 extended to sampled clients):

      * state rows merge with the participation weights u (stale deltas
        discounted), exactly like ``masked_average_state``;
      * the variates refresh ONLY over the participants: fresh cv rows are
        pre-scaled by the binary mask m, a second weight lane ships
        P = Σm, and the new global variate is cg = Σ_k m_k·cv_new_k / P —
        the exact participant mean;
      * each participant stores its own fresh cv_new (re-quantized under
        int8, as in the unmasked path); an absent worker keeps its old
        c_k, so its corrections stay consistent until it rejoins.

    Still ONE collective per dtype bucket; masked payload = unmasked
    + 8 bytes (the u and m lanes)."""
    u = faults["weights"].astype(jnp.float32)
    r = faults["resync"].astype(jnp.float32)
    m = (u > 0).astype(jnp.float32)
    mats, meta, kloc = _state_mats(state)
    cmats, cmeta, _ = _state_mats(cv_new)
    smats, smeta = _masked_sketch_mats(state, m)
    n, nc = len(mats), len(cmats)
    lanes = jnp.stack([u, m], axis=1)        # [K_loc, 2] f32
    if ring is not None:
        if compress:
            raise ValueError("ring averaging does not support compressed "
                             "buckets")
        scaled = [mt * u.astype(mt.dtype)[:, None] for mt in mats]
        cscaled = [mt * m.astype(mt.dtype)[:, None] for mt in cmats]
        sums = ring_sum_buckets(scaled + cscaled + [lanes] + smats, ring)
    elif compress == "int8":
        from repro.core import coda

        if smats:  # unreachable via CoDAConfig; guard direct callers
            raise ValueError("the streaming-eval sketch cannot ride int8 "
                             "compressed buckets")
        all_means = masked_int8_average(mats + cmats, [0] * n + [1] * nc,
                                        lanes, wa)
        means, cmeans = all_means[:n], all_means[n:]
        ssums = []
        # each worker re-applies the wire quantizer to its OWN variate rows
        # (locally), so cg == participant-mean of the stored cv_k exactly
        stored = []
        for mt in cmats:
            q, s = coda.int8_quantize(mt.astype(jnp.float32), (1,))
            stored.append((q.astype(jnp.float32) * s).astype(mt.dtype))
        cmats = stored
    else:
        scaled = [mt * u.astype(mt.dtype)[:, None] for mt in mats]
        cscaled = [mt * m.astype(mt.dtype)[:, None] for mt in cmats]
        sums = psum_buckets(scaled + cscaled + [lanes] + smats, wa)
    if compress != "int8":
        W = jnp.maximum(sums[n + nc][0], 1.0)
        P = jnp.maximum(sums[n + nc][1], 1.0)
        means = [s.astype(jnp.float32) / W for s in sums[:n]]
        cmeans = [s.astype(jnp.float32) / P for s in sums[n:n + nc]]
        ssums = sums[n + nc + 1:]
    take = jnp.maximum(m, r)
    params, duals = _select_rows(meta, kloc, means, take)
    ctree, cduals = _unmats(cmeta, kloc, cmeans)
    new = dict(state)
    new["params"] = params
    new["duals"] = duals
    if smeta is not None:
        new = _apply_masked_sketch_sums(new, smeta, ssums, m)
    new["cg_params"], new["cg_duals"] = ctree, cduals
    # cv_k ← fresh variate for participants, unchanged for absent workers
    cflat, ctdef = cmeta
    fresh_flat = [mt.reshape(l.shape) for mt, l in zip(cmats, cflat)]
    fresh = jax.tree_util.tree_unflatten(ctdef, fresh_flat)
    old = {"params": state["cv_params"], "duals": state["cv_duals"]}
    msel = lambda f_, o_: jnp.where(
        m.reshape((kloc,) + (1,) * (o_.ndim - 1)) > 0,
        f_.astype(o_.dtype), o_)
    cv = jax.tree_util.tree_map(msel, fresh, old)
    new["cv_params"], new["cv_duals"] = cv["params"], cv["duals"]
    return new
