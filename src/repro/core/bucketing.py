"""Bucketed cross-worker averaging shared by the executors.

Both the vmap oracle (``wa=()``: plain axis-0 means, nothing crosses a
wire) and the shard_map executor (``wa=("data",)`` etc.: one ``lax.pmean``
per dtype bucket) run *these* functions, so the two paths cannot drift:
the oracle's arithmetic is the sharded executor's arithmetic with the
collective removed.

Two payload layouts:

  * ``average_state`` — plain CoDA: the state tensors (params + a, b, α)
    form one concatenated bucket per dtype; fp32 default = exactly one
    all-reduce of ``coda.model_bytes(state)`` operand bytes.
  * ``average_and_refresh`` — CODASCA: the freshly computed per-worker
    control variates ride the SAME bucket as the state tensors, so the
    global control variate c = mean_k c_k costs zero extra rounds — the
    window still lowers to exactly ONE all-reduce, now of
    ``2 × model_bytes`` (state + control payload, HLO-asserted in
    tests/test_codasca.py).

``compress="int8"`` swaps the fp32 pmean for an s8-payload + fp32-scale
all-gather pair (see ``coda.int8_quantize``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def pmean_buckets(mats, wa):
    """Mean the [K_loc, n_i] matrices over the global worker axis, shipping
    one concatenated bucket per dtype (one all-reduce each; exactly one for
    the default all-fp32 state).  Returns the [n_i] means."""
    by_dtype = {}
    for i, m in enumerate(mats):
        by_dtype.setdefault(jnp.dtype(m.dtype), []).append(i)
    out = [None] * len(mats)
    for idxs in by_dtype.values():
        buf = jnp.concatenate([mats[i] for i in idxs], axis=1)
        mean = jnp.mean(buf, axis=0)
        if wa:
            mean = jax.lax.pmean(mean, wa)
        offs = np.cumsum([0] + [mats[i].shape[1] for i in idxs])
        for j, i in enumerate(idxs):
            out[i] = mean[offs[j]:offs[j + 1]]
    return out


def int8_average(mats, wa):
    """Compressed averaging: per-(worker, tensor) max-abs fp32 scales, int8
    payload.  Only the s8 bucket and the fp32 scales cross the wire (one
    all-gather each); dequantize + mean happen on every shard."""
    from repro.core import coda

    qs, scales = [], []
    for m in mats:
        q, scale = coda.int8_quantize(m.astype(jnp.float32), (1,))
        qs.append(q)
        scales.append(scale)
    qbuf = jnp.concatenate(qs, axis=1)       # [K_loc, N] int8 payload
    sbuf = jnp.concatenate(scales, axis=1)   # [K_loc, L] fp32 scales
    if wa:
        qbuf = jax.lax.all_gather(qbuf, wa, axis=0, tiled=True)
        sbuf = jax.lax.all_gather(sbuf, wa, axis=0, tiled=True)
    out, off = [], 0
    for i, m in enumerate(mats):
        n = m.shape[1]
        deq = qbuf[:, off:off + n].astype(jnp.float32) * sbuf[:, i:i + 1]
        out.append(jnp.mean(deq, axis=0).astype(m.dtype))
        off += n
    return out


def _state_mats(state):
    """The CoDA state as a flat list of [K_loc, n_i] matrices + treedef."""
    flat_p, tdef = jax.tree_util.tree_flatten(state["params"])
    kloc = flat_p[0].shape[0]
    mats = [l.reshape(kloc, -1) for l in flat_p] + \
           [state[k].reshape(kloc, 1) for k in ("a", "b", "alpha")]
    return mats, flat_p, tdef, kloc


def _unmats(flat_p, tdef, kloc, means, *, broadcast=True):
    """Means back into a params tree + (a, b, α) scalars."""
    outs = []
    for m, mean in zip(flat_p, means[:len(flat_p)]):
        trail = m.shape[1:]
        r = mean.reshape(trail)
        if broadcast:
            r = jnp.broadcast_to(r, (kloc,) + trail)
        outs.append(r.astype(m.dtype))
    tree = jax.tree_util.tree_unflatten(tdef, outs)
    scalars = []
    for i, mean in enumerate(means[len(flat_p):len(flat_p) + 3]):
        s = jnp.broadcast_to(mean, (kloc,)) if broadcast else mean
        scalars.append(s.astype(jnp.float32))
    return tree, scalars


def average_state(state, wa, compress: Optional[str]):
    """``coda.average`` semantics on a local worker shard: mean over the
    K_loc local workers, then over the worker mesh axes."""
    mats, flat_p, tdef, kloc = _state_mats(state)
    means = int8_average(mats, wa) if compress == "int8" \
        else pmean_buckets(mats, wa)
    tree, (a, b, alpha) = _unmats(flat_p, tdef, kloc, means)
    new = dict(state)
    new["params"] = tree
    new["a"], new["b"], new["alpha"] = a, b, alpha
    return new


def average_and_refresh(state, cv_new, wa, compress: Optional[str]):
    """CODASCA window end: average the state tensors AND the per-worker
    control variates in one bucket.  The state mean is broadcast back (all
    workers restart from the synced iterate), the control mean becomes the
    new global variate ``cg_*``, and each worker keeps its OWN ``cv_new``
    as ``cv_*`` — c_k never crosses the wire except through its mean.

    ``cv_new``: dict with the same layout as the state's averaged slice
    ({"params": tree, "a", "b", "alpha": [K_loc]}).

    Under ``compress="int8"`` the *dequantized* variates are stored as
    ``cv_*`` — c and c_k must share the quantizer, or the corrections
    ``c − c_k`` pick up a common bias of one quantization step per window
    and the K=1 / homogeneous CODASCA ≡ CoDA equivalences break.
    """
    mats, flat_p, tdef, kloc = _state_mats(state)
    cmats, cflat, _, _ = _state_mats(cv_new)
    if compress == "int8":
        from repro.core import coda

        means = int8_average(mats + cmats, wa)
        # each worker re-applies the wire quantizer to its OWN variate rows
        # (locally — nothing extra crosses the wire), so cg == mean_k cv_k
        # holds exactly under quantization
        stored = []
        for m in cmats:
            q, s = coda.int8_quantize(m.astype(jnp.float32), (1,))
            stored.append((q.astype(jnp.float32) * s).astype(m.dtype))
        cmats = stored
    else:
        means = pmean_buckets(mats + cmats, wa)
    n = len(mats)
    tree, (a, b, alpha) = _unmats(flat_p, tdef, kloc, means[:n])
    ctree, (ca, cb, calpha) = _unmats(cflat, tdef, kloc, means[n:])
    new = dict(state)
    new["params"] = tree
    new["a"], new["b"], new["alpha"] = a, b, alpha
    new["cg_params"], new["cg_a"], new["cg_b"], new["cg_alpha"] = \
        ctree, ca, cb, calpha
    stored_flat = [m.reshape(l.shape) for m, l in zip(cmats[:len(cflat)], cflat)]
    new["cv_params"] = jax.tree_util.tree_unflatten(tdef, stored_flat)
    for mat, k in zip(cmats[len(cflat):], ("cv_a", "cv_b", "cv_alpha")):
        new[k] = mat.reshape(kloc)
    return new
