"""Seed-deterministic fault-injection for windowed training.

A ``FaultPlan`` schedules three fault kinds against the K-worker window
loop, replayable from a seed:

  * **dropout** — with probability ``dropout`` a worker misses one window:
    its delta never reaches the wire (weight 0) but it receives the merged
    broadcast (resync 1), the standard partial-participation model of
    Yuan et al. 2021 (sampled clients compute, every client restarts the
    next round from the server state).
  * **straggle** — with probability ``straggle`` a worker's window delta is
    delayed by ``straggle_windows`` windows.  While in flight it is absent
    AND keeps its own local state (resync 0 — it never saw the broadcasts).
    On arrival, a delay d ≤ ``max_staleness`` merges the stale delta with
    the staleness-discounted weight ``staleness_discount ** d``; beyond
    that the delta is dropped and the worker only re-syncs from the merged
    state (graceful degradation — the round never waits).
  * **crash** — ``crashes = ((worker, window), ...)``: from its crash
    window on, a worker contributes weight 0 forever and passively tracks
    the merged state (its replica stays shaped so the compiled window
    program is unchanged — a crash is a data event, not a shape event).

Per window ``w`` the plan yields two float32 [K] vectors consumed by the
masked window averaging (core/bucketing.py):

  * ``weights`` u_k — the worker's contribution weight in the masked mean
    (1 fresh, 0 absent, ``discount**d`` for a rejoining straggler);
  * ``resync`` r_k — 1 if the worker adopts the merged state after the
    collective, 0 if it keeps its own iterate (mid-straggle only).

The schedule is computed sequentially (window w depends on the straggle
history of windows < w) and cached, so ``window(w)`` is cheap and two
plans built from the same arguments replay identically — that is the
determinism contract tests/test_faults.py pins and the crash-recovery
resume path relies on.  The plan never yields an all-absent window: it
first re-admits a dropped worker, else force-merges an in-flight
straggler; if every worker has crashed it raises (there is no one left to
train).

``staleness_discount`` defaults to 0.5: powers of two survive the cast to
bf16 wire buckets exactly, so the mask-prescaled contributions stay exact
under mixed-precision states.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seed-replayable per-window fault schedule for K workers."""
    n_workers: int
    seed: int = 0
    dropout: float = 0.0           # per-window per-worker dropout prob
    straggle: float = 0.0          # per-window prob a fresh worker straggles
    straggle_windows: int = 1      # straggler delay d, measured in windows
    max_staleness: int = 0         # merge stale deltas up to this delay
    staleness_discount: float = 0.5
    crashes: tuple = ()            # ((worker, window), ...): permanent deaths

    def __post_init__(self):
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {self.dropout}")
        if not 0.0 <= self.straggle < 1.0:
            raise ValueError(f"straggle must be in [0, 1), got "
                             f"{self.straggle}")
        if self.straggle_windows < 1:
            raise ValueError(f"straggle_windows must be >= 1, got "
                             f"{self.straggle_windows}")
        if self.max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0, got "
                             f"{self.max_staleness}")
        if not 0.0 < self.staleness_discount <= 1.0:
            raise ValueError(f"staleness_discount must be in (0, 1], got "
                             f"{self.staleness_discount}")
        for c in self.crashes:
            k, w = c
            if not (0 <= k < self.n_workers) or w < 0:
                raise ValueError(f"bad crash entry {c!r} for "
                                 f"{self.n_workers} workers")
        # the sequential schedule cache: windows are generated in order from
        # one Generator so window w's straggle state sees windows < w.  A
        # frozen dataclass may still carry mutable cache state.
        object.__setattr__(self, "_rng", np.random.default_rng(self.seed))
        object.__setattr__(self, "_windows", [])
        object.__setattr__(self, "_straggling",
                           np.zeros(self.n_workers, np.int64))
        object.__setattr__(self, "_crash_at",
                           {k: w for k, w in self.crashes})

    @classmethod
    def from_config(cls, ccfg) -> "FaultPlan":
        """Build the plan a ``CoDAConfig``'s fault knobs describe (the path
        ``coda.fit`` takes when ``ccfg.faults_enabled``)."""
        return cls(
            n_workers=ccfg.n_workers,
            seed=ccfg.fault_seed,
            dropout=1.0 - ccfg.participation,
            straggle=ccfg.straggler_prob,
            straggle_windows=ccfg.straggler_windows,
            max_staleness=ccfg.max_staleness,
            staleness_discount=ccfg.staleness_discount,
            crashes=tuple(ccfg.crashes),
        )

    # -- schedule generation ------------------------------------------------
    def _next_window(self):
        """Append one window to the cache (called in window order only)."""
        w = len(self._windows)
        K = self.n_workers
        # both vectors are drawn every window regardless of worker state so
        # the random stream — and therefore the whole schedule — is a pure
        # function of (seed, window index)
        drop = self._rng.random(K) < self.dropout
        sflip = self._rng.random(K) < self.straggle
        u = np.ones(K, np.float32)
        r = np.ones(K, np.float32)
        dropped, in_flight = [], []
        for k in range(K):
            if self._crash_at.get(k, w + 1) <= w:
                u[k] = 0.0                       # dead: weight 0, track merged
                continue
            if self._straggling[k] > 0:
                self._straggling[k] -= 1
                if self._straggling[k] == 0:     # stale delta arrives now
                    d = self.straggle_windows
                    if d <= self.max_staleness:
                        u[k] = np.float32(self.staleness_discount) ** d
                    else:
                        u[k] = 0.0               # too stale: drop + re-sync
                else:                            # still in flight
                    u[k], r[k] = 0.0, 0.0
                    in_flight.append(k)
                continue
            if sflip[k]:
                self._straggling[k] = self.straggle_windows
                u[k], r[k] = 0.0, 0.0
                in_flight.append(k)
                continue
            if drop[k]:
                u[k] = 0.0
                dropped.append(k)
        if float(u.sum()) == 0.0:
            # never an all-absent window: re-admit a dropped worker, else
            # force-merge an in-flight straggler at full weight
            if dropped:
                u[dropped[0]] = 1.0
            elif in_flight:
                k = in_flight[0]
                self._straggling[k] = 0
                u[k], r[k] = 1.0, 1.0
            else:
                raise RuntimeError(
                    "FaultPlan: every worker has crashed before window "
                    f"{w}; no participants remain")
        self._windows.append((u, r))

    def window(self, w: int):
        """(weights, resync) float32 [K] vectors for window ``w``."""
        if w < 0:
            raise ValueError(f"window index must be >= 0, got {w}")
        while len(self._windows) <= w:
            self._next_window()
        u, r = self._windows[w]
        return u.copy(), r.copy()

    def participants(self, w: int) -> np.ndarray:
        """Binary participation mask for window ``w`` (u_k > 0)."""
        u, _ = self.window(w)
        return (u > 0).astype(np.float32)
