"""The paper's primary contribution: CoDA (Alg. 1+2), its objective, the
Theorem-1 schedules, and the paper's baselines (PPD-SG / NP-PPD-SG)."""
from repro.core import baselines, coda, objective, schedules  # noqa: F401
from repro.core.coda import (  # noqa: F401
    CoDAConfig, average, comm_bytes, comm_rounds, fit, init_state, local_step,
    make_executor, model_bytes, stage_end, window_step)
