"""The paper's primary contribution: CoDA (Alg. 1+2), its objective, the
Theorem-1 schedules, the paper's baselines (PPD-SG / NP-PPD-SG), and the
beyond-paper CODASCA variant for heterogeneous shards."""
from repro.core import baselines, coda, codasca, objective, schedules  # noqa: F401
from repro.core.coda import (  # noqa: F401
    CoDAConfig, average, comm_bytes, comm_rounds, fit, init_state, local_step,
    make_executor, model_bytes, stage_end, window_payload_bytes, window_step)
