"""Baselines the paper compares against.

* PPD-SG  (Liu et al. 2020b)  — single machine: CoDA with K = 1, I = 1.
* NP-PPD-SG                    — naive parallel: CoDA with I = 1 (gradient
  averaging every step; Table 1 row 2).
* Parallel minibatch SGD on binary cross-entropy — the "standard loss
  minimization" strawman of the introduction, for AUC-vs-BCE comparisons.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import coda, objective
from repro.models import model as M


def params_k(params) -> int:
    """The stacked worker count of a [K, ...] parameter tree."""
    return jax.tree_util.tree_leaves(params)[0].shape[0]


def ppd_sg_config(ccfg: coda.CoDAConfig) -> coda.CoDAConfig:
    return dataclasses.replace(ccfg, n_workers=1)


def np_ppd_sg_window(mcfg, ccfg, state, window_batch, eta):
    """NP-PPD-SG = average after *every* local step (I=1 semantics even if
    the batch carries a window axis)."""

    def body(st, wb):
        st, losses = coda.local_step(mcfg, ccfg, st, wb, eta)
        return coda.average(st), jnp.mean(losses)

    return jax.lax.scan(body, state, window_batch)


# --------------------------------------------------------------------------
# BCE-SGD baseline (loss minimization, not AUC)
# --------------------------------------------------------------------------
def bce_init(key, mcfg: ModelConfig, K: int, dtype=jnp.float32):
    params = M.init_params(key, mcfg, dtype=dtype)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), params)


def bce_step(mcfg: ModelConfig, params, batch, eta, *, impl="auto"):
    """One synchronous parallel-SGD step on BCE (gradient averaging).

    The loss is the registered dual-free ``bce`` objective routed through
    the same scoring closure the CoDA executors trace
    (``coda._worker_loss`` with the empty dual tree) — no duplicated
    score/clip/log plumbing here."""
    obj = objective.REGISTRY["bce"]()
    ccfg = coda.CoDAConfig(n_workers=params_k(params), objective="bce",
                           impl=impl)

    def loss_fn(p, wb):
        # _worker_loss returns (loss, scores): the scores ride as aux for
        # the streaming-eval sketch; plain SGD only needs the loss
        return coda._worker_loss(mcfg, ccfg, obj, p, {}, wb)

    (losses, _), grads = jax.vmap(
        jax.value_and_grad(loss_fn, has_aux=True))(params, batch)
    # synchronous data parallelism: average the gradients across workers
    grads = jax.tree_util.tree_map(
        lambda g: jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True), g.shape),
        grads)
    params = jax.tree_util.tree_map(lambda p, g: p - eta * g, params, grads)
    return params, jnp.mean(losses)
