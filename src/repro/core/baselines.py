"""Baselines the paper compares against.

* PPD-SG  (Liu et al. 2020b)  — single machine: CoDA with K = 1, I = 1.
* NP-PPD-SG                    — naive parallel: CoDA with I = 1 (gradient
  averaging every step; Table 1 row 2).
* Parallel minibatch SGD on binary cross-entropy — the "standard loss
  minimization" strawman of the introduction, for AUC-vs-BCE comparisons.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import coda
from repro.models import model as M


def ppd_sg_config(ccfg: coda.CoDAConfig) -> coda.CoDAConfig:
    return dataclasses.replace(ccfg, n_workers=1)


def np_ppd_sg_window(mcfg, ccfg, state, window_batch, eta):
    """NP-PPD-SG = average after *every* local step (I=1 semantics even if
    the batch carries a window axis)."""

    def body(st, wb):
        st, losses = coda.local_step(mcfg, ccfg, st, wb, eta)
        return coda.average(st), jnp.mean(losses)

    return jax.lax.scan(body, state, window_batch)


# --------------------------------------------------------------------------
# BCE-SGD baseline (loss minimization, not AUC)
# --------------------------------------------------------------------------
def bce_init(key, mcfg: ModelConfig, K: int, dtype=jnp.float32):
    params = M.init_params(key, mcfg, dtype=dtype)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), params)


def bce_step(mcfg: ModelConfig, params, batch, eta, *, impl="auto"):
    """One synchronous parallel-SGD step on BCE (gradient averaging)."""

    def loss_fn(p, wb):
        inputs = {k: v for k, v in wb.items() if k != "labels"}
        h, aux = M.score(mcfg, p, inputs, train=True, impl=impl)
        h = jnp.clip(h, 1e-6, 1 - 1e-6)
        y = wb["labels"]
        return -jnp.mean(y * jnp.log(h) + (1 - y) * jnp.log(1 - h)) + 0.01 * aux

    losses, grads = jax.vmap(jax.value_and_grad(loss_fn))(params, batch)
    # synchronous data parallelism: average the gradients across workers
    grads = jax.tree_util.tree_map(
        lambda g: jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True), g.shape),
        grads)
    params = jax.tree_util.tree_map(lambda p, g: p - eta * g, params, grads)
    return params, jnp.mean(losses)
