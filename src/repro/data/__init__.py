from repro.data.synthetic import DataConfig, ShardedDataset, sample_online  # noqa: F401
