"""Synthetic imbalanced binary-classification data with a planted signal.

Three modalities matching the model families:
  * tokens   — positive sequences over-sample a motif token set; a scoring
               model must learn to detect motif density.
  * images   — two Gaussian class means over [H, W, 3] pixels (CIFAR-like,
               for the paper-faithful ResNet experiments).
  * features — flat Gaussian features (fast CPU experiments).

Both of the paper's settings are supported:
  * online   — every draw samples y ~ Bernoulli(p) fresh (P_k = P for all k).
  * batch    — a fixed dataset is built once, negatives dropped to reach the
               target positive ratio (the paper keeps all positives and drops
               negatives to reach p = 0.71), then *partitioned* across the K
               workers so machine k only ever sees shard k (P_k = empirical
               distribution of its shard).

The batch partition has two modes:
  * IID (``dirichlet_alpha=None`` / ∞) — shuffle and split evenly, the
    paper's setting: every shard's label ratio matches the global p.
  * non-IID (``dirichlet_alpha=α``) — Dirichlet(α) label skew, the standard
    federated-learning recipe: for each class, a Dir(α·1_K) draw decides
    what fraction of that class each worker receives.  Small α ⇒ extreme
    skew (some workers see almost no positives), α → ∞ ⇒ IID.  Every
    sample — in particular every positive — is assigned to exactly one
    shard; shard sizes become unequal, and the per-shard positive ratios
    (``shard_p_pos``) spread around the global p.  This is the
    heterogeneous regime CODASCA (core/codasca.py) corrects for.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    kind: str = "tokens"       # tokens | images | features
    p_pos: float = 0.5
    vocab_size: int = 512
    seq_len: int = 64
    image_hw: int = 32
    n_features: int = 64
    signal: float = 1.0        # planted signal strength
    motif_frac: float = 0.1    # fraction of vocab that is "motif" tokens
    d_model: int = 128         # for frame/patch stubs
    hard_neg_frac: float = 0.0  # features only: fraction of negatives drawn
                                # from a near-positive "hard" component (see
                                # _draw) — the regime where partial-AUC
                                # training (objective="pauc_dro") beats
                                # full-AUC at equal comm rounds


def _draw(key, dcfg: DataConfig, shape, labels):
    """labels: [...], returns input dict with matching leading dims."""
    if dcfg.kind == "tokens":
        n_motif = max(1, int(dcfg.vocab_size * dcfg.motif_frac))
        k1, k2, k3 = jax.random.split(key, 3)
        base = jax.random.randint(k1, shape + (dcfg.seq_len,), 0, dcfg.vocab_size)
        motif = jax.random.randint(k2, shape + (dcfg.seq_len,), 0, n_motif)
        # positives get motif tokens with prob signal*0.25
        use = jax.random.uniform(k3, shape + (dcfg.seq_len,)) < (
            dcfg.signal * 0.25 * labels[..., None])
        return {"tokens": jnp.where(use, motif, base)}
    if dcfg.kind == "images":
        hw = dcfg.image_hw
        x = jax.random.normal(key, shape + (hw * hw, 3))
        mean = (labels[..., None, None] * 2 - 1) * dcfg.signal * 0.2
        return {"images": x + mean}
    if dcfg.hard_neg_frac > 0.0:
        # Heteroscedastic negatives: a (1-q) "easy" bulk at −0.3·s along the
        # primary feature block, plus a q "hard" component sitting at
        # +0.25·s — nearly on top of the positives there.  Telling hard
        # negatives from positives requires the SECONDARY feature block
        # (pos +0.2·s, hard negs −0.2·s, easy negs 0).  A full-AUC
        # objective spends its gradient on the bulk pairs; DRO-weighted
        # partial AUC focuses on the hard component and learns the
        # secondary direction first — the planted asymmetry the
        # objective_sweep benchmark measures.
        kx, kh = jax.random.split(key)
        x = jax.random.normal(kx, shape + (dcfg.n_features,))
        half = dcfg.n_features // 2
        hard = ((jax.random.uniform(kh, shape) < dcfg.hard_neg_frac)
                & (labels < 0.5)).astype(jnp.float32)
        s = dcfg.signal
        prim = jnp.where(hard > 0.5, 0.25 * s, (labels * 2 - 1) * 0.3 * s)
        sec = 0.2 * s * labels - 0.2 * s * hard
        x = x.at[..., :half].add(prim[..., None])
        x = x.at[..., half:].add(sec[..., None])
        return {"features": x}
    x = jax.random.normal(key, shape + (dcfg.n_features,))
    mean = (labels[..., None] * 2 - 1) * dcfg.signal * 0.3
    return {"features": x + mean}


def sample_online(key, dcfg: DataConfig, shape) -> dict:
    """Online setting: iid draws, y ~ Bernoulli(p).  ``shape`` e.g. (I,K,B)."""
    kl, kx = jax.random.split(key)
    labels = (jax.random.uniform(kl, shape) < dcfg.p_pos).astype(jnp.float32)
    batch = _draw(kx, dcfg, shape, labels)
    batch["labels"] = labels
    return batch


# --------------------------------------------------------------------------
# batch setting: fixed dataset, imbalance by dropping negatives, shard by K
# --------------------------------------------------------------------------
def dirichlet_partition(rng: np.random.RandomState, labels: np.ndarray,
                        n_workers: int, alpha: float):
    """Dirichlet(α) label-skew partition: per class c, q_c ~ Dir(α·1_K)
    decides the fraction of class-c samples each worker gets.

    Returns K index arrays that tile [0, n) exactly (every sample — every
    positive — lands in exactly one shard).  Empty shards are topped up
    from the largest shard so every worker can draw minibatches.
    """
    shards = [[] for _ in range(n_workers)]
    for c in np.unique(labels):
        idx = rng.permutation(np.nonzero(labels == c)[0])
        q = rng.dirichlet(np.full(n_workers, alpha))
        cuts = np.round(np.cumsum(q)[:-1] * len(idx)).astype(int)
        for k, part in enumerate(np.split(idx, cuts)):
            shards[k].append(part)
    shards = [np.concatenate(s) for s in shards]
    for k in range(n_workers):  # no worker may starve
        while len(shards[k]) == 0:
            donor = int(np.argmax([len(s) for s in shards]))
            shards[k], shards[donor] = shards[donor][-1:], shards[donor][:-1]
    return [rng.permutation(s) for s in shards]


class ShardedDataset:
    """Fixed dataset partitioned across K workers (machine k sees shard k).

    ``dirichlet_alpha=None`` (or ∞): the paper's IID even split.  A finite
    value turns on Dirichlet(α) label-skew — see the module docstring.
    """

    def __init__(self, key, dcfg: DataConfig, n: int, n_workers: int,
                 target_p: float | None = None,
                 dirichlet_alpha: float | None = None):
        self.dcfg = dcfg
        kl, kx, kp = jax.random.split(key, 3)
        labels = (jax.random.uniform(kl, (n,)) < 0.5).astype(jnp.float32)
        if target_p is not None and target_p > 0.5:
            # keep all positives, drop negatives (paper §5 "Data")
            keep_neg = (1 - target_p) / target_p
            u = jax.random.uniform(kp, (n,))
            keep = (labels > 0.5) | (u < keep_neg)
            idx = jnp.nonzero(keep, size=n, fill_value=-1)[0]
            idx = np.asarray(idx[idx >= 0])
            labels = labels[idx]
            n = len(idx)
        batch = _draw(kx, dcfg, (n,), labels)
        self.inputs = {k: np.asarray(v) for k, v in batch.items()}
        self.labels = np.asarray(labels)
        self.n = n
        self.K = n_workers
        self.p_pos = float(self.labels.mean())
        self.dirichlet_alpha = dirichlet_alpha
        rng = np.random.RandomState(0)
        if dirichlet_alpha is None or not np.isfinite(dirichlet_alpha):
            # shuffle then partition evenly (paper: "shuffled and evenly
            # divided") — the IID / α = ∞ limit
            perm = rng.permutation(n)
            per = n // n_workers
            self.shards = [perm[k * per:(k + 1) * per]
                           for k in range(n_workers)]
        else:
            self.shards = dirichlet_partition(rng, self.labels, n_workers,
                                              dirichlet_alpha)
        self.shard_sizes = [len(s) for s in self.shards]
        self.shard_p_pos = [float(self.labels[s].mean()) if len(s) else 0.0
                            for s in self.shards]

    def sample_window(self, key, I: int, B: int) -> dict:
        """[I, K, B, ...] minibatches; worker k draws only from shard k."""
        rng = np.random.RandomState(int(jax.random.randint(key, (), 0, 2 ** 31 - 1)))
        idx = np.stack([
            np.stack([rng.choice(self.shards[k], size=B) for k in range(self.K)])
            for _ in range(I)])  # [I, K, B]
        out = {k: jnp.asarray(v[idx]) for k, v in self.inputs.items()}
        out["labels"] = jnp.asarray(self.labels[idx])
        return out

    def sample_alpha_batch(self, key, m: int) -> dict:
        # no clamping to the smallest shard: draws are with replacement, and
        # under Dirichlet skew one starved shard must not collapse every
        # worker's stage-end α re-estimate to a single sample
        rng = np.random.RandomState(int(jax.random.randint(key, (), 0, 2 ** 31 - 1)))
        idx = np.stack([rng.choice(self.shards[k], size=m) for k in range(self.K)])
        out = {k: jnp.asarray(v[idx]) for k, v in self.inputs.items()}
        out["labels"] = jnp.asarray(self.labels[idx])
        return out

    def full(self, max_n: int = 4096) -> dict:
        n = min(self.n, max_n)
        out = {k: jnp.asarray(v[:n]) for k, v in self.inputs.items()}
        out["labels"] = jnp.asarray(self.labels[:n])
        return out
