"""Shared metric-reporting plumbing for the launch CLIs.

``launch/train.py`` and ``launch/serve.py`` used to each hand-format their
own AUC printouts; both now take the same flags (``--metrics
{exact,sketch}``, ``--metric-interval``, ``--metric-bins``) from
``add_metric_args`` and print through ``IntervalReporter`` /
``metric_line`` so a training window report and a serving traffic report
read identically:

    [train] window 40: streaming auc=0.9312 ±0.0041 (sketch) n=10240 state=2048B
    [serve] req 32: streaming auc=0.4987 ±0.0113 (sketch) n=32 state=2048B
"""
from __future__ import annotations

from repro.metrics import streaming


def add_metric_args(ap, *, interval_default: int = 0):
    """Install the shared metric flags on an argparse parser."""
    g = ap.add_argument_group("metrics")
    g.add_argument("--metrics", default="exact",
                   choices=["exact", "sketch"],
                   help="evaluation backend: exact materialises scores; "
                        "sketch streams them through a fixed-size "
                        "mergeable histogram (repro.metrics.streaming)")
    g.add_argument("--metric-interval", type=int, default=interval_default,
                   help="report streaming metrics every N units (train: "
                        "windows; serve: finished requests); 0 = final only")
    g.add_argument("--metric-bins", type=int, default=streaming.DEFAULT_BINS,
                   help="sketch bins (state = 2*bins*4 bytes)")
    return g


def metric_line(label: str, tick, metric: streaming.Metric, state, *,
                n_seen=None) -> str:
    """One uniform report line for a metric state."""
    val = metric.finalize(state)
    res = metric.resolution(state)
    parts = [f"[{label}] {tick}: streaming {metric.name}={val:.4f}"]
    if res > 0:
        parts.append(f"±{res:.4f}")
    parts.append(f"({metric.backend})")
    if n_seen is not None:
        parts.append(f"n={n_seen}")
    parts.append(f"state={metric.state_bytes(state)}B")
    return " ".join(parts)


class IntervalReporter:
    """Cadenced printing of a metric state.

    ``tick(t, state_fn)`` prints every ``interval`` units (``state_fn`` is
    called lazily so exact test-set scoring only happens at report ticks);
    ``report(t, state)`` prints unconditionally (final summaries).  The
    last finalized value is kept on ``.last`` for callers that also log it.
    """

    def __init__(self, metric: streaming.Metric, *, interval: int = 0,
                 label: str = "metrics", printer=print):
        self.metric = metric
        self.interval = int(interval)
        self.label = label
        self.printer = printer
        self.last = None
        self._next = self.interval

    def tick(self, t: int, state_fn) -> bool:
        if self.interval <= 0 or t < self._next:
            return False
        self.report(t, state_fn())
        while self._next <= t:
            self._next += self.interval
        return True

    def report(self, t, state, *, n_seen=None) -> float:
        self.last = self.metric.finalize(state)
        self.printer(metric_line(self.label, t, self.metric, state,
                                 n_seen=n_seen))
        return self.last
