"""Shared metric-reporting plumbing for the launch CLIs.

``launch/train.py`` and ``launch/serve.py`` used to each hand-format their
own AUC printouts; both now take the same flags (``--metrics
{exact,sketch}``, ``--metric-interval``, ``--metric-bins``) from
``add_metric_args`` and print through ``IntervalReporter`` /
``metric_line`` so a training window report and a serving traffic report
read identically:

    [train] window 40: streaming auc=0.9312 ±0.0041 (sketch) n=10240 state=2048B
    [serve] req 32: streaming auc=0.4987 ±0.0113 (sketch) n=32 state=2048B
"""
from __future__ import annotations

from repro.metrics import streaming


def add_metric_args(ap, *, interval_default: int = 0):
    """Install the shared metric flags on an argparse parser."""
    g = ap.add_argument_group("metrics")
    g.add_argument("--metrics", default="exact",
                   choices=["exact", "sketch"],
                   help="evaluation backend: exact materialises scores; "
                        "sketch streams them through a fixed-size "
                        "mergeable histogram (repro.metrics.streaming)")
    g.add_argument("--metric-interval", type=int, default=interval_default,
                   help="report streaming metrics every N units (train: "
                        "windows; serve: finished requests); 0 = final only")
    g.add_argument("--metric-bins", type=int, default=streaming.DEFAULT_BINS,
                   help="sketch bins (state = 2*bins*4 bytes)")
    return g


# warn when more than this fraction of the scored mass was (or may have
# been) saturated at the sketch range ends — past that, the end bins hold
# unordered mass and the reported AUC resolution no longer bounds the error
CLIP_WARN_FRACTION = 0.01
# edge-bin mass is only meaningful as a clipping proxy when the end bins
# are a small slice of the range; with few bins they legitimately hold a
# large share of any score distribution
_EDGE_MASS_MIN_BINS = 64


def _clip_warning(sk: streaming.ScoreSketch) -> str | None:
    """Saturation warning for a sketch state, or None.

    Host-built sketches carry exact under/overflow counters; device-lifted
    ones (``sketch_from_rows``) don't — the counters never ride the wire —
    so fall back to end-bin mass, the observable upper bound."""
    if sk.clipped > CLIP_WARN_FRACTION:
        return (f"WARN clipped={sk.clipped:.1%} "
                f"(under={int(sk.under)} over={int(sk.over)}) of scores "
                f"saturated outside [{sk.lo:g}, {sk.hi:g}) — widen the "
                f"sketch range")
    if (sk.under == 0 and sk.over == 0 and sk.bins >= _EDGE_MASS_MIN_BINS
            and sk.edge_mass > CLIP_WARN_FRACTION):
        return (f"WARN edge-bin mass={sk.edge_mass:.1%} — scores may be "
                f"clipping at [{sk.lo:g}, {sk.hi:g}); widen the sketch "
                f"range")
    return None


def metric_line(label: str, tick, metric: streaming.Metric, state, *,
                n_seen=None) -> str:
    """One uniform report line for a metric state."""
    val = metric.finalize(state)
    res = metric.resolution(state)
    parts = [f"[{label}] {tick}: streaming {metric.name}={val:.4f}"]
    if res > 0:
        parts.append(f"±{res:.4f}")
    parts.append(f"({metric.backend})")
    if n_seen is not None:
        parts.append(f"n={n_seen}")
    parts.append(f"state={metric.state_bytes(state)}B")
    if isinstance(state, streaming.ScoreSketch):
        warn = _clip_warning(state)
        if warn:
            parts.append(warn)
    return " ".join(parts)


def worker_skew_line(label: str, tick, metric: streaming.Metric,
                     sk_loc, lo: float, hi: float) -> str:
    """Per-worker AUC skew from the local (never-averaged) sketch lanes.

    ``sk_loc`` is the training state's ``[K, bins]`` per-worker subtree
    (``state["sk_loc"]``): lane k holds exactly worker k's own stream, so
    under heterogeneous sharding this line shows how far individual
    workers' local AUC sits from the merged global figure — at zero extra
    wire bytes.  Lanes with no data yet, or a single-class stream (extreme
    label skew can hand a worker only one label; AUC is undefined there,
    not 0), report "-"."""
    sks = streaming.worker_sketches(sk_loc, lo, hi)
    vals = [metric.finalize(sk)
            if float(sk.pos.sum()) > 0 and float(sk.neg.sum()) > 0 else None
            for sk in sks]
    live = [v for v in vals if v is not None]
    cells = " ".join(f"{v:.3f}" if v is not None else "-" for v in vals)
    parts = [f"[{label}] {tick}: worker {metric.name} [{cells}]"]
    if live:
        spread = max(live) - min(live)
        parts.append(f"spread={spread:.4f}")
    return " ".join(parts)


class IntervalReporter:
    """Cadenced printing of a metric state.

    ``tick(t, state_fn)`` prints every ``interval`` units (``state_fn`` is
    called lazily so exact test-set scoring only happens at report ticks);
    ``report(t, state)`` prints unconditionally (final summaries).  The
    last finalized value is kept on ``.last`` for callers that also log it.
    """

    def __init__(self, metric: streaming.Metric, *, interval: int = 0,
                 label: str = "metrics", printer=print):
        self.metric = metric
        self.interval = int(interval)
        self.label = label
        self.printer = printer
        self.last = None
        self._next = self.interval

    def tick(self, t: int, state_fn) -> bool:
        if self.interval <= 0 or t < self._next:
            return False
        self.report(t, state_fn())
        while self._next <= t:
            self._next += self.interval
        return True

    def report(self, t, state, *, n_seen=None) -> float:
        self.last = self.metric.finalize(state)
        self.printer(metric_line(self.label, t, self.metric, state,
                                 n_seen=n_seen))
        return self.last
