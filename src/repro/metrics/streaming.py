"""Mergeable score sketches for streaming tie-aware AUC / pAUC@FPR≤β.

AUC is a *pairwise* metric: the exact estimators in ``core/objective.py``
(``roc_auc``, ``partial_auc``) materialise every score before ranking, so
neither a long training run nor the serving engine can report AUC over a
stream that does not fit in memory.  This module replaces the materialised
score vector with a fixed-size sketch:

  * ``ScoreSketch`` — two fp32 count vectors ``pos[B]``, ``neg[B]`` over
    ``B`` equal-width bins spanning ``[lo, hi)`` (scores outside the range
    are clipped into the end bins).  State is ``2·B·4`` bytes regardless of
    how many scores were seen.
  * ``update(sk, scores, labels)`` — histogram a batch of (score, label)
    pairs.  Binning is done in fp32 with one shared scale constant, so the
    host (NumPy) path and the traced jnp path (``update_counts``, used by
    the training executors) place every score in the same bin.
  * ``merge(a, b)`` — elementwise count addition.  Counts are
    integer-valued fp32, so addition is *exact* (hence associative and
    commutative, with the empty sketch as identity) while every count stays
    below 2^24 — merge order across workers, shards, or time windows cannot
    change the result.
  * ``finalize`` → ``auc_from_counts`` / ``pauc_from_counts``.

Estimator and resolution bound
------------------------------
With per-bin counts p_b (positives) and n_b (negatives), P = Σp_b,
N = Σn_b, the sketch AUC is the tie-aware rank statistic computed *as if*
every score sat at its bin's representative point:

    AUC_sketch = Σ_b p_b · (N_<b + n_b/2) / (P·N),   N_<b = Σ_{b'<b} n_{b'}

Error analysis, pair by pair (the exact tie-aware AUC scores a (pos, neg)
pair 1 if pos > neg, 1/2 if tied, 0 otherwise):

  * cross-bin pairs are scored exactly: bin membership is monotone in the
    score (equal-width bins; clipping maps scores beyond an end bin *into*
    that end bin, which never reorders a pair across different bins), so a
    positive in a higher bin than a negative really does outscore it — and
    exactly tied scores always share a bin, so a tie is never split across
    bins;
  * same-bin pairs are scored 1/2 by the sketch but lie anywhere in [0, 1]
    exactly, so each contributes at most 1/2 error.

Hence the *computable* deterministic bound reported by ``auc_resolution``:

    |AUC_sketch − AUC_exact| ≤ Σ_b p_b·n_b / (2·P·N)

For pAUC@FPR≤β the exact estimator (``objective.partial_auc``) ranks the
positives against the k = max(1, ceil(β·N)) highest-scoring negatives.  The
sketch selects the same k negatives *by bin* — whole bins from the top down
plus a partial count r from the cutoff bin c (which negatives of bin c are
"selected" is ambiguous, but they are mutually tied at sketch resolution,
and the exact top-k picks *some* k−Σ_{b>c}n_b of them, so the selected sets
differ only inside bin c — covered by the same-bin term):

    |pAUC_sketch − pAUC_exact| ≤ (Σ_{b>c} p_b·n_b + p_c·r) / (2·P·k)

Both bounds are monotone non-increasing under dyadic bin refinement
(splitting a bin can only split its p_b·n_b mass across sub-bins:
Σ p_i·n_i ≤ (Σp_i)(Σn_i) for non-negative counts), which is the
"error shrinks with sketch size" property the tests pin.

Degenerate-input conventions match the exact estimators: no positives or
no negatives → 0.0 (and resolution 0.0); all scores tied → 1/2 from the
same-bin term, exactly the exact estimator's value (the bound is loose but
valid there: |1/2 − 1/2| = 0 ≤ 1/2).

The ``Metric`` protocol
-----------------------
``Metric`` is the redesigned evaluation API (it replaces the removed
``Objective.eval_metric`` attribute): ``init() → state``,
``update(state, scores, labels) → state``, ``merge(a, b) → state``,
``finalize(state) → float``, plus ``resolution``/``state_bytes``
introspection and a ``compute`` convenience for one-shot evaluation.  Two
drop-in backends:

  * ``exact``  (``ExactMetric``) — accumulates raw score/label chunks and
    finalizes through ``objective.roc_auc`` / ``objective.partial_auc``,
    numerically identical to the pre-redesign path; O(n) state.
  * ``sketch`` (``SketchMetric``) — the sketch above; O(B) state.

``make_metric(kind, backend)`` builds either; objectives expose their
reporting metric via ``Objective.metric(backend)``.

Training integration: when ``CoDAConfig.stream_bins > 0`` both executors
keep per-worker sketch *deltas* (``sk_new``) updated every local step from
the scores the loss already computes, and the window average folds the
worker-summed deltas into a replicated accumulator (``sk_acc``) riding the
existing fp32 window bucket — still ONE all-reduce per window, payload
delta exactly ``2·stream_bins·4`` bytes (asserted against compiled HLO in
the tests).  The deltas are pre-scaled by ``n_workers`` so the collective's
*mean* is the exact integer *sum*: mean(K·c) = (Σ K·c)/K has an exact
integer numerator and an exactly-representable integer quotient, so even
through fp32 averaging the merged counts are exact.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

DEFAULT_BINS = 2048
DEFAULT_RANGE: tuple[float, float] = (-8.0, 8.0)


# --------------------------------------------------------------------------
# binning — one fp32 formula shared by the host and traced paths
# --------------------------------------------------------------------------
def _scale(lo: float, hi: float, bins: int) -> float:
    """The fp32 bins/(hi−lo) factor; computed once in python float so the
    NumPy and jnp paths multiply by the *same* constant."""
    return float(np.float32(bins / (hi - lo)))


def _bin_index_np(scores, lo: float, hi: float, bins: int) -> np.ndarray:
    s = np.asarray(scores, np.float32).ravel()
    t = (np.clip(s, np.float32(lo), np.float32(hi)) - np.float32(lo))
    idx = np.floor(t * np.float32(_scale(lo, hi, bins))).astype(np.int64)
    return np.clip(idx, 0, bins - 1)


def bin_index(scores, lo: float, hi: float, bins: int):
    """Traced twin of the host binning: identical fp32 ops, same bins."""
    s = scores.astype(jnp.float32)
    t = jnp.clip(s, lo, hi) - jnp.float32(lo)
    idx = jnp.floor(t * jnp.float32(_scale(lo, hi, bins))).astype(jnp.int32)
    return jnp.clip(idx, 0, bins - 1)


def update_counts(pos, neg, scores, labels, lo: float, hi: float):
    """One worker's traced sketch update: scatter-add a batch of scores
    into fp32 count vectors ``pos``/``neg`` of shape [bins]."""
    bins = pos.shape[-1]
    idx = bin_index(scores.reshape(-1), lo, hi, bins)
    w = (labels.reshape(-1) > 0.5).astype(jnp.float32)
    return pos.at[idx].add(w), neg.at[idx].add(1.0 - w)


# --------------------------------------------------------------------------
# the host-side sketch
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ScoreSketch:
    """Fixed-size mergeable (pos, neg) score histogram; see module doc.

    ``under``/``over`` count the scores that fell outside ``[lo, hi)`` and
    were saturated into an end bin.  The fixed default range is a silent
    failure mode — a model whose logits drift past ``hi`` piles mass into
    the top bin and the sketch AUC quietly degrades toward a coin flip —
    so the clip events are counted where they happen.  The counters are
    host-side observability only: they do NOT ride the training wire (the
    window payload stays ``pos``/``neg``), so device-lifted sketches
    (``sketch_from_rows``) carry zeros and expose ``edge_mass`` as the
    observable upper bound instead."""

    pos: np.ndarray  # fp32 [bins] positive-score counts
    neg: np.ndarray  # fp32 [bins] negative-score counts
    lo: float
    hi: float
    under: float = 0.0  # scores < lo, saturated into bin 0
    over: float = 0.0   # scores >= hi, saturated into bin B-1

    @property
    def bins(self) -> int:
        return int(self.pos.shape[-1])

    @property
    def nbytes(self) -> int:
        return int(self.pos.nbytes + self.neg.nbytes)

    @property
    def count(self) -> int:
        return int(float(self.pos.sum() + self.neg.sum()))

    @property
    def clipped(self) -> float:
        """Exact fraction of observed scores saturated at the range ends
        (0.0 when the counters didn't travel — see class doc)."""
        c = self.count
        return float(self.under + self.over) / c if c else 0.0

    @property
    def edge_mass(self) -> float:
        """Fraction of all counts in the two end bins — ≥ the clipped
        fraction by construction (every clipped score lands in an end
        bin), and computable from wire counts alone."""
        c = self.count
        if not c:
            return 0.0
        return float(self.pos[0] + self.pos[-1] +
                     self.neg[0] + self.neg[-1]) / c


def empty_sketch(bins: int = DEFAULT_BINS, lo: float = DEFAULT_RANGE[0],
                 hi: float = DEFAULT_RANGE[1]) -> ScoreSketch:
    if bins < 1:
        raise ValueError(f"bins must be >= 1, got {bins}")
    if not hi > lo:
        raise ValueError(f"need hi > lo, got [{lo}, {hi})")
    return ScoreSketch(np.zeros(bins, np.float32), np.zeros(bins, np.float32),
                       float(lo), float(hi))


def update(sk: ScoreSketch, scores, labels) -> ScoreSketch:
    """Histogram a batch of (score, label) pairs; returns a new sketch."""
    s = np.asarray(scores, np.float32).ravel()
    y = np.asarray(labels, np.float32).ravel()
    if s.shape != y.shape:
        raise ValueError(f"scores {s.shape} vs labels {y.shape}")
    idx = _bin_index_np(s, sk.lo, sk.hi, sk.bins)
    pos, neg = sk.pos.copy(), sk.neg.copy()
    is_pos = y > 0.5
    np.add.at(pos, idx[is_pos], np.float32(1.0))
    np.add.at(neg, idx[~is_pos], np.float32(1.0))
    under = sk.under + float(np.count_nonzero(s < np.float32(sk.lo)))
    over = sk.over + float(np.count_nonzero(s >= np.float32(sk.hi)))
    return ScoreSketch(pos, neg, sk.lo, sk.hi, under, over)


def merge(a: ScoreSketch, b: ScoreSketch) -> ScoreSketch:
    """Exact (associative, commutative) elementwise count addition."""
    if a.bins != b.bins or a.lo != b.lo or a.hi != b.hi:
        raise ValueError(
            f"incompatible sketches: {a.bins}@[{a.lo},{a.hi}) vs "
            f"{b.bins}@[{b.lo},{b.hi})")
    return ScoreSketch(a.pos + b.pos, a.neg + b.neg, a.lo, a.hi,
                       a.under + b.under, a.over + b.over)


def sketch_from_rows(sk_tree, lo: float, hi: float,
                     row: int = 0) -> ScoreSketch:
    """Lift one replicated row of a training-state sketch subtree
    (``state["sk_acc"]`` — {"pos": [K, B], "neg": [K, B]}) to a host
    ``ScoreSketch``.  After a window average every row is identical, so
    row 0 is the global accumulator."""
    return ScoreSketch(np.asarray(sk_tree["pos"][row], np.float32),
                       np.asarray(sk_tree["neg"][row], np.float32),
                       float(lo), float(hi))


def worker_sketches(sk_tree, lo: float, hi: float) -> list:
    """Lift EVERY lane of a per-worker sketch subtree to host sketches —
    one ``ScoreSketch`` per worker row.

    Meant for ``state["sk_loc"]``, the local (never-averaged) twin of the
    merged accumulator: each worker folds only its OWN deltas into its
    lane, so after any number of windows lane k holds exactly the raw
    counts of worker k's local stream — per-worker AUC skew comes straight
    off the existing ``[K, 2, bins]`` readout with zero extra wire bytes
    (the window collective never touches ``sk_loc``)."""
    K = int(np.asarray(sk_tree["pos"]).shape[0])
    return [sketch_from_rows(sk_tree, lo, hi, row=k) for k in range(K)]


# --------------------------------------------------------------------------
# finalize: counts → AUC / pAUC + computable resolution bounds
# --------------------------------------------------------------------------
def _counts64(pos, neg):
    p = np.asarray(pos, np.float64).ravel()
    n = np.asarray(neg, np.float64).ravel()
    return p, n, float(p.sum()), float(n.sum())


def auc_from_counts(pos, neg) -> float:
    """Tie-aware AUC from bin counts (same-bin pairs score 1/2)."""
    p, n, P, N = _counts64(pos, neg)
    if P <= 0 or N <= 0:
        return 0.0
    below = np.concatenate([[0.0], np.cumsum(n)[:-1]])
    return float(np.sum(p * (below + 0.5 * n)) / (P * N))


def auc_resolution(pos, neg) -> float:
    """Deterministic bound on |AUC_sketch − AUC_exact| (module doc)."""
    p, n, P, N = _counts64(pos, neg)
    if P <= 0 or N <= 0:
        return 0.0
    return float(np.sum(p * n) / (2.0 * P * N))


def _select_hard_negatives(n: np.ndarray, k: int) -> np.ndarray:
    """Per-bin counts of the k highest-scoring negatives: whole bins from
    the top down, a partial count in the cutoff bin."""
    above = np.cumsum(n[::-1])[::-1] - n  # negatives in strictly higher bins
    return np.clip(float(k) - above, 0.0, n)


def _pauc_k(beta: float, N: float) -> int:
    # textually the exact estimator's k (objective.partial_auc) so the two
    # agree on which FPR budget "k negatives" means
    return max(1, int(np.ceil(beta * N)))


def pauc_from_counts(pos, neg, beta: float) -> float:
    """Tie-aware pAUC@FPR≤β from bin counts: positives ranked against the
    k = max(1, ceil(β·N)) hardest negatives, selected by bin."""
    p, n, P, N = _counts64(pos, neg)
    if P <= 0 or N <= 0:
        return 0.0
    sel = _select_hard_negatives(n, _pauc_k(beta, N))
    k = float(sel.sum())
    below = np.concatenate([[0.0], np.cumsum(sel)[:-1]])
    return float(np.sum(p * (below + 0.5 * sel)) / (P * k))


def pauc_resolution(pos, neg, beta: float) -> float:
    """Deterministic bound on |pAUC_sketch − pAUC_exact| (module doc)."""
    p, n, P, N = _counts64(pos, neg)
    if P <= 0 or N <= 0:
        return 0.0
    sel = _select_hard_negatives(n, _pauc_k(beta, N))
    k = float(sel.sum())
    return float(np.sum(p * sel) / (2.0 * P * k))


# --------------------------------------------------------------------------
# the Metric protocol + backends
# --------------------------------------------------------------------------
class Metric:
    """Mergeable evaluation metric: ``init``/``update``/``merge``/
    ``finalize`` (+ ``resolution``/``state_bytes`` introspection).

    The redesigned successor of ``Objective.eval_metric``: state is an
    explicit value, so evaluation composes across batches, workers, and
    time by ``merge`` instead of by materialising one giant score vector.
    """

    name: str = "metric"
    backend: str = ""

    def init(self):
        raise NotImplementedError

    def update(self, state, scores, labels):
        raise NotImplementedError

    def merge(self, a, b):
        raise NotImplementedError

    def finalize(self, state) -> float:
        raise NotImplementedError

    def resolution(self, state) -> float:
        """Bound on |finalize(state) − exact|; 0.0 for exact backends."""
        return 0.0

    def state_bytes(self, state) -> int:
        raise NotImplementedError

    def compute(self, scores, labels) -> float:
        """One-shot convenience: init → update → finalize."""
        return self.finalize(self.update(self.init(), scores, labels))


class ExactMetric(Metric):
    """Materialise-everything backend, numerically identical to the old
    ``eval_metric`` path: state is a list of (scores, labels) chunks,
    finalized through ``objective.roc_auc`` / ``objective.partial_auc``."""

    backend = "exact"

    def __init__(self, beta: float | None = None):
        self.beta = None if beta is None else float(beta)
        self.name = "auc" if beta is None else "pauc"

    def init(self):
        return []

    def update(self, state, scores, labels):
        s = np.asarray(scores, np.float32).ravel()
        y = np.asarray(labels, np.float32).ravel()
        if s.shape != y.shape:
            raise ValueError(f"scores {s.shape} vs labels {y.shape}")
        return list(state) + [(s, y)]

    def merge(self, a, b):
        return list(a) + list(b)

    def finalize(self, state) -> float:
        from repro.core import objective  # deferred: objective builds Metrics

        if not state:
            return 0.0
        s = np.concatenate([c[0] for c in state])
        y = np.concatenate([c[1] for c in state])
        if self.beta is None:
            return float(objective.roc_auc(jnp.asarray(s), jnp.asarray(y)))
        return objective.partial_auc(s, y, self.beta)

    def state_bytes(self, state) -> int:
        return int(sum(c[0].nbytes + c[1].nbytes for c in state))


class SketchMetric(Metric):
    """Fixed-size streaming backend over ``ScoreSketch`` states."""

    backend = "sketch"

    def __init__(self, beta: float | None = None, *,
                 bins: int = DEFAULT_BINS, lo: float = DEFAULT_RANGE[0],
                 hi: float = DEFAULT_RANGE[1]):
        empty_sketch(bins, lo, hi)  # validate once, loudly
        self.beta = None if beta is None else float(beta)
        self.name = "auc" if beta is None else "pauc"
        self.bins, self.lo, self.hi = int(bins), float(lo), float(hi)

    def init(self) -> ScoreSketch:
        return empty_sketch(self.bins, self.lo, self.hi)

    def update(self, state, scores, labels):
        return update(state, scores, labels)

    def merge(self, a, b):
        return merge(a, b)

    def finalize(self, state) -> float:
        if self.beta is None:
            return auc_from_counts(state.pos, state.neg)
        return pauc_from_counts(state.pos, state.neg, self.beta)

    def resolution(self, state) -> float:
        if self.beta is None:
            return auc_resolution(state.pos, state.neg)
        return pauc_resolution(state.pos, state.neg, self.beta)

    def state_bytes(self, state) -> int:
        return state.nbytes


def make_metric(kind: str = "auc", backend: str = "exact", *,
                beta: float = 0.3, bins: int = DEFAULT_BINS,
                lo: float = DEFAULT_RANGE[0],
                hi: float = DEFAULT_RANGE[1]) -> Metric:
    """Build a metric: ``kind`` ∈ {auc, pauc}, ``backend`` ∈ {exact, sketch}.
    ``beta`` applies to pauc only; ``bins``/``lo``/``hi`` to sketch only."""
    if kind not in ("auc", "pauc"):
        raise ValueError(f"unknown metric kind {kind!r} (auc | pauc)")
    b = beta if kind == "pauc" else None
    if backend == "exact":
        return ExactMetric(b)
    if backend == "sketch":
        return SketchMetric(b, bins=bins, lo=lo, hi=hi)
    raise ValueError(f"unknown metric backend {backend!r} (exact | sketch)")
