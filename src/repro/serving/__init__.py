from repro.serving import decode, engine, loadgen  # noqa: F401
from repro.serving.decode import (  # noqa: F401
    cache_specs, init_cache, masked_chunk_step, prefill, serve_step)
from repro.serving.engine import Request, ServingEngine, TicksExhausted  # noqa: F401
