from repro.serving import decode, engine  # noqa: F401
from repro.serving.decode import cache_specs, init_cache, prefill, serve_step  # noqa: F401
from repro.serving.engine import Request, ServingEngine  # noqa: F401
