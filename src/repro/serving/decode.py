"""Autoregressive serving: KV-cache construction and the one-token
``serve_step`` that the decode input shapes (decode_32k, long_500k) lower.

The layer loop is *unrolled* in Python (vs the scanned training stack) so
that heterogeneous per-layer cache shapes are possible:
  * full-attention layers   — [B, S, KV, hd] caches,
  * sliding-window layers   — [B, W, KV, hd] ring buffers (this is what makes
    ``long_500k`` sub-quadratic-memory for Hymba and windowed dense archs),
  * mamba branches          — O(1) conv + SSM state,
  * xLSTM blocks            — O(1) matrix/scalar memory, no length-S cache,
  * enc-dec                 — precomputed cross-attention K/V + short self cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import blocks, xlstm as xl
from repro.models.embeddings import apply_norm, embed
from repro.models.mlp import apply_mlp
from repro.models.moe import apply_moe
from repro.models.model import lm_logits
from repro.models.ssm import decode_ssm, init_ssm_state


def _layer_params(params_layers, i: int):
    """Slice layer i out of a stacked layer pytree."""
    return jax.tree_util.tree_map(lambda a: a[i], params_layers)


# --------------------------------------------------------------------------
# cache construction
# --------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, B: int, S: int, *, use_window: bool = True,
               dtype=jnp.bfloat16):
    """Cache pytree for a decode session of maximum length S."""
    if cfg.family == "ssm":
        layers = []
        for kind in blocks.xlstm_layer_kinds(cfg):
            if kind == "slstm":
                layers.append({"slstm": xl.init_slstm_state(cfg, B)})
            else:
                layers.append({"mlstm": xl.init_mlstm_state(cfg, B)})
        return {"layers": layers}
    wins = blocks.layer_windows_static(cfg, use_window)
    layers = []
    for i in range(cfg.n_layers):
        lc = {"attn": A.init_cache(cfg, B, S, ring=wins[i] is not None, dtype=dtype)}
        if cfg.family == "hybrid":
            lc["ssm"] = init_ssm_state(cfg, B)
        if cfg.is_encoder_decoder:
            # cross-attention K/V over the encoded sequence (filled at prefill)
            lc["enc_k"] = jnp.zeros((B, S, cfg.n_kv_heads, cfg.head_dim), dtype)
            lc["enc_v"] = jnp.zeros((B, S, cfg.n_kv_heads, cfg.head_dim), dtype)
            # decoder self-attention cache is short (S // decoder_fraction)
            lc["attn"] = A.init_cache(cfg, B, max(1, S // cfg.decoder_fraction),
                                      ring=False, dtype=dtype)
        layers.append(lc)
    return {"layers": layers}


def cache_specs(cfg: ModelConfig, B: int, S: int, *, use_window: bool = True,
                dtype=jnp.bfloat16):
    """ShapeDtypeStruct mirror of ``init_cache`` (dry-run: no allocation)."""
    return jax.eval_shape(
        lambda: init_cache(cfg, B, S, use_window=use_window, dtype=dtype))


def encode_for_decode(cfg: ModelConfig, params, cache, frames, *, impl="auto"):
    """Enc-dec archs: run the encoder over frame embeddings and fill every
    decoder layer's cross-attention K/V."""
    from repro.models.model import _encdec_encoder  # local import, small helper

    enc, _ = _encdec_encoder(cfg, params, frames, impl=impl)
    B, Se = enc.shape[:2]
    new_layers = []
    for i in range(cfg.n_layers):
        lp = _layer_params(params["layers"], i)
        lc = dict(cache["layers"][i])
        cp = lp["cross"]
        k = (enc @ cp["wk"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        v = (enc @ cp["wv"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qkv_bias:
            k, v = k + cp["bk"].reshape(1, 1, cfg.n_kv_heads, -1), v + cp["bv"].reshape(1, 1, cfg.n_kv_heads, -1)
        lc["enc_k"] = k.astype(lc["enc_k"].dtype)
        lc["enc_v"] = v.astype(lc["enc_v"].dtype)
        new_layers.append(lc)
    return {"layers": new_layers}


# --------------------------------------------------------------------------
# serve_step
# --------------------------------------------------------------------------
def serve_step(cfg: ModelConfig, params, cache, tokens, positions, *,
               use_window: bool = True, impl: str = "auto"):
    """Decode ONE token.  tokens: [B, 1]; positions: [B].

    Returns (logits [B, vocab], score_logit [B], new_cache).
    """
    x = embed(params["embed"], tokens)  # [B, 1, d]
    new_layers = []
    if cfg.family == "ssm":
        for i, kind in enumerate(blocks.xlstm_layer_kinds(cfg)):
            lp = params["layers"][i]
            lc = cache["layers"][i]
            h = apply_norm(cfg, lp["norm1"], x)
            if kind == "slstm":
                o, st = xl.decode_slstm(cfg, lp["core"], lc["slstm"], h)
                new_layers.append({"slstm": st})
            else:
                o, st = xl.decode_mlstm(cfg, lp["core"], lc["mlstm"], h)
                new_layers.append({"mlstm": st})
            x = x + o
    else:
        wins = blocks.layer_windows_static(cfg, use_window)
        for i in range(cfg.n_layers):
            lp = _layer_params(params["layers"], i)
            lc = cache["layers"][i]
            nc = {}
            h = apply_norm(cfg, lp["norm1"], x)
            a, nc["attn"] = A.decode_step(cfg, lp["attn"], lc["attn"], h,
                                          positions, window=wins[i])
            if cfg.family == "hybrid":
                s, nc["ssm"] = decode_ssm(cfg, lp["ssm"],
                                          lc["ssm"], apply_norm(cfg, lp["norm_h"], x))
                a = 0.5 * (a + s)
            x = x + a
            if cfg.is_encoder_decoder:
                hx = apply_norm(cfg, lp["norm_x"], x)
                x = x + A.cross_decode(cfg, lp["cross"], lc["enc_k"], lc["enc_v"], hx)
                nc["enc_k"], nc["enc_v"] = lc["enc_k"], lc["enc_v"]
            h2 = apply_norm(cfg, lp["norm2"], x)
            if "moe" in lp:
                y, _ = apply_moe(cfg, lp["moe"], h2, impl=impl)
            else:
                y = apply_mlp(cfg, lp["mlp"], h2)
            x = x + y
            new_layers.append(nc)

    h = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params, h[:, 0])
    sh = params["score_head"]
    score_logit = (h[:, 0] @ sh["w"])[:, 0].astype(jnp.float32) + sh["b"][0]
    return logits, score_logit, {"layers": new_layers}


# --------------------------------------------------------------------------
# prefill (fills the cache from a prompt; used by the serving engine)
# --------------------------------------------------------------------------
def masked_chunk_step(cfg: ModelConfig, params, cache, tokens, positions,
                      n_tokens, *, use_window: bool = True,
                      impl: str = "auto"):
    """Batched chunked prefill/decode: feed each batch row up to C tokens.

    The continuous-batching engine's device step — the same scan over
    ``serve_step`` that ``prefill`` runs, generalized to heterogeneous rows:
    slots mid-prefill consume up to C prompt tokens per call while slots in
    decode (or free slots) consume one (or zero).

      tokens:    [B, C] int32 — row s feeds tokens[s, :n_tokens[s]]
      positions: [B]    int32 — row s's first token lands at positions[s]
      n_tokens:  [B]    int32 — live steps per row (0 => row is idle)

    Rows are independent through the whole model (attention reads only the
    row's own cache line; routing/norms are per-token), so masking is a
    per-row select: step t computes ``serve_step`` for every row but rows
    with ``t >= n_tokens`` keep their previous cache bitwise.  Every cache
    leaf carries the row (slot) axis at dim 0 — the engine-wide contract
    ``ServingEngine._reset_slot`` enforces.

    Returns ``(cache, argmax_tokens [B, C] int32, score_logits [B, C] f32)``;
    outputs at dead steps (t >= n_tokens[s]) are garbage and must be ignored
    by the caller.
    """
    B, C = tokens.shape

    def body(cache, t):
        live = t < n_tokens
        logits, score, new_cache = serve_step(
            cfg, params, cache, tokens[:, t][:, None], positions + t,
            use_window=use_window, impl=impl)

        def sel(n, o):
            return jnp.where(live.reshape((B,) + (1,) * (n.ndim - 1)), n, o)

        cache = jax.tree_util.tree_map(sel, new_cache, cache)
        return cache, (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                       score.astype(jnp.float32))

    cache, (toks_out, scores) = jax.lax.scan(body, cache, jnp.arange(C))
    return cache, toks_out.T, scores.T


def prefill(cfg: ModelConfig, params, cache, tokens, *, use_window=True,
            impl: str = "auto"):
    """Sequential prefill via serve_step (simple and cache-exact; the batch
    engine amortizes it across requests).  tokens: [B, S0].

    Only the LAST token's logits are observable, so the scan carries the
    cache alone — the old per-token [B, vocab] logits carry forced a
    vocab-sized copy through every scan iteration and kept S0−1 dead
    lm_head matmuls live.  The final step runs outside the scan and
    produces the fp32 logits that tests/test_decode_consistency.py pins
    against the parallel forward (token-by-token MoE dispatch included)."""
    B, S0 = tokens.shape

    def body(cache, t):
        _, _, cache = serve_step(
            cfg, params, cache, tokens[:, t][:, None],
            jnp.full((B,), t, jnp.int32), use_window=use_window, impl=impl)
        return cache, None

    cache, _ = jax.lax.scan(body, cache, jnp.arange(S0 - 1))
    logits, _, cache = serve_step(
        cfg, params, cache, tokens[:, S0 - 1][:, None],
        jnp.full((B,), S0 - 1, jnp.int32), use_window=use_window, impl=impl)
    return cache, logits.astype(jnp.float32)
