"""A small-but-real batched serving engine on top of ``serve_step``.

Continuous batching over a fixed number of slots: requests (prompt token
lists) are admitted into free slots, prefilled token-by-token through the
same jitted ``serve_step`` (cache-exact), then decoded greedily until EOS or
``max_new_tokens``.  Finished slots are recycled.  This is the driver behind
``examples/serve_requests.py`` and the serving integration tests.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving import decode as D


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, use_window: bool = True,
                 impl: str = "auto"):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.use_window = use_window
        self.impl = impl
        self.cache = D.init_cache(cfg, slots, max_len, use_window=use_window,
                                  dtype=jnp.float32)
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int64)        # next position per slot
        self.pending = [deque() for _ in range(slots)]  # unconsumed prompt tokens
        self._step = jax.jit(
            lambda params, cache, tok, pos: D.serve_step(
                cfg, params, cache, tok, pos, use_window=use_window,
                impl=impl))

    def add_request(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self.active[s] = req
                self.pos[s] = 0
                self.pending[s] = deque(req.prompt)
                self.cache = self._reset_slot(s)

    def _reset_slot(self, s: int):
        fresh = D.init_cache(self.cfg, 1, self.max_len,
                             use_window=self.use_window, dtype=jnp.float32)

        def put(old, new):
            return old.at[s:s + 1].set(new) if hasattr(old, "at") else old

        return jax.tree_util.tree_map(put, self.cache, fresh)

    def step(self) -> int:
        """One engine tick: feeds every active slot one token (prompt token
        during prefill, previously-sampled token during decode).  Returns the
        number of active requests."""
        self._admit()
        tok = np.zeros((self.slots, 1), np.int32)
        pos = np.zeros((self.slots,), np.int32)
        feeding = [False] * self.slots
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if self.pending[s]:
                tok[s, 0] = self.pending[s].popleft()
            elif req.generated:
                tok[s, 0] = req.generated[-1]
            else:
                continue
            pos[s] = self.pos[s]
            feeding[s] = True
        if not any(feeding):
            return 0
        logits, _, self.cache = self._step(self.params, self.cache,
                                           jnp.asarray(tok), jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s, req in enumerate(self.active):
            if req is None or not feeding[s]:
                continue
            self.pos[s] += 1
            if not self.pending[s]:  # decoding phase: the output token counts
                req.generated.append(int(nxt[s]))
                if (len(req.generated) >= req.max_new_tokens
                        or int(nxt[s]) == req.eos_id
                        or self.pos[s] >= self.max_len - 1):
                    req.done = True
                    self.active[s] = None
        return sum(r is not None for r in self.active) + len(self.queue)

    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if self.step() == 0 and not self.queue:
                return
