"""Continuous-batching serving engine for trained AUC/pAUC scorers.

The engine multiplexes a fixed number of KV-cache *slots* over a stream of
requests:

  * **Admission** — a bounded FIFO (or shortest-job-first) queue; requests
    are validated at the door (empty prompts rejected, over-``max_len``
    prompts truncated or rejected — never silently clamp-written past the
    cache) and stamped with arrival/admission/first-token/completion
    timestamps for latency accounting.  Optional per-request deadlines
    expire requests that wait or run too long.
  * **Batched chunked prefill** — every engine tick issues ONE device call
    (``decode.masked_chunk_step``, the same scan over ``serve_step`` that
    ``decode.prefill`` runs): slots mid-prefill consume up to
    ``prefill_chunk`` prompt tokens while slots in decode consume their one
    feedback token, so prompt ingestion is amortized across the batch
    instead of one token per tick per slot.
  * **Prefix cache** — optionally (``prefix_cache_size > 0``) the
    post-prompt cache slice of each completed prefill is kept in an LRU
    keyed on the prompt tokens; a new request whose prompt extends a cached
    prefix skips straight to the suffix (exact: the cached slice *is* the
    state after the shared tokens).
  * **Slot recycling** — ``_reset_slot`` writes a fresh (or prefix-cached)
    state into the slot along the explicit slot axis (dim 0 of every cache
    leaf) and raises on any leaf that violates the contract rather than
    silently leaving it stale.

Decoding is greedy; the per-request ``score`` field records the AUC head's
logit at the last prompt token (the scorer output this serving path
exists to deliver).  Encoder-decoder configs are not served here (their
prefill consumes frames, not tokens).  Drivers: ``launch/serve.py``,
``examples/serve_requests.py``, ``benchmarks/run.py --only serve_load``
(via ``serving.loadgen``), and tests/test_serving_engine.py.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from functools import partial
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving import decode as D


class TicksExhausted(RuntimeError):
    """``run()`` ran out of ticks with requests still queued or active.

    ``records`` carries the partial per-request state of everything still
    in flight (uid, status, tokens generated so far, positions consumed,
    timestamps) so the caller can account for the unfinished work instead
    of losing the whole trace."""

    def __init__(self, message: str, records: list[dict] | None = None):
        super().__init__(message)
        self.records = records or []


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int = -1
    deadline: float | None = None     # seconds after arrival; None = none
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = "new"          # new|queued|active|done|rejected|expired|failed
    reject_reason: str = ""
    failure_reason: str = ""     # set when status == "failed" (or when the
                                 # metric fold failed on an otherwise-served
                                 # request — outcome kept, failure recorded)
    truncated: bool = False
    prompt_used: list[int] = dataclasses.field(default_factory=list)
    prefix_hit_tokens: int = 0
    score: float | None = None        # AUC-head logit at the last prompt token
    label: float | None = None        # ground truth when the trace carries
                                         # one (loadgen labeled traces) — feeds
                                         # the engine's streaming-AUC sketch
    # latency accounting (engine clock, seconds)
    t_arrival: float | None = None
    t_admitted: float | None = None
    t_first_token: float | None = None
    t_complete: float | None = None

    @property
    def ttft(self) -> float | None:
        if self.t_first_token is None or self.t_arrival is None:
            return None
        return self.t_first_token - self.t_arrival

    @property
    def latency(self) -> float | None:
        if self.t_complete is None or self.t_arrival is None:
            return None
        return self.t_complete - self.t_arrival


@partial(jax.jit, static_argnums=(0,), static_argnames=("use_window", "impl"))
def _chunk_step(cfg, params, cache, tokens, positions, n_tokens, *,
                use_window, impl):
    return D.masked_chunk_step(cfg, params, cache, tokens, positions,
                               n_tokens, use_window=use_window, impl=impl)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, use_window: bool = True,
                 impl: str = "auto", prefill_chunk: int = 8,
                 queue_limit: int | None = None, admission: str = "fifo",
                 on_overflow: str = "truncate", prefix_cache_size: int = 0,
                 metric=None,
                 clock: Callable[[], float] = time.monotonic):
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "encoder-decoder configs need encode_for_decode; the engine "
                "serves token-prompt architectures")
        if admission not in ("fifo", "sjf"):
            raise ValueError(f"unknown admission policy {admission!r}")
        if on_overflow not in ("truncate", "reject"):
            raise ValueError(f"unknown overflow policy {on_overflow!r}")
        if prefill_chunk < 1 or slots < 1 or max_len < 2:
            raise ValueError((prefill_chunk, slots, max_len))
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.use_window = use_window
        self.impl = impl
        self.prefill_chunk = prefill_chunk
        self.queue_limit = queue_limit
        self.admission = admission
        self.on_overflow = on_overflow
        self.prefix_cache_size = prefix_cache_size
        self._clock = clock
        self.cache = D.init_cache(cfg, slots, max_len, use_window=use_window,
                                  dtype=jnp.float32)
        self._fresh = D.init_cache(cfg, 1, max_len, use_window=use_window,
                                   dtype=jnp.float32)
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self.pos = np.zeros(slots, np.int32)            # next position per slot
        self.pending = [deque() for _ in range(slots)]  # unconsumed prompt toks
        self._prefix: OrderedDict = OrderedDict()       # prompt tuple -> slice
        # counters
        self.ticks = 0
        self.tokens_prefilled = 0
        self.tokens_decoded = 0
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.n_completed = 0
        self.n_rejected = 0
        self.n_expired = 0
        self.n_failed = 0
        # streaming metric over served traffic: a repro.metrics.streaming
        # Metric (usually AUC, sketch backend).  Every finalized request
        # that carries both a score and a ground-truth label is folded into
        # the mergeable state — including expired requests that were scored
        # before their deadline hit (they were served traffic too).
        self.metric = metric
        self.metric_state = metric.init() if metric is not None else None
        self.n_scored = 0

    # -- admission ----------------------------------------------------------
    def add_request(self, req: Request) -> bool:
        """Validate and enqueue.  Returns False (request finalized with
        ``status="rejected"``) on empty prompts, non-positive generation
        budgets, a full queue, or — under ``on_overflow="reject"`` — prompts
        that do not fit the cache."""
        if req.t_arrival is None:
            req.t_arrival = self._clock()
        if not req.prompt:
            return self._reject(req, "empty_prompt")
        if req.max_new_tokens < 1:
            return self._reject(req, "non_positive_max_new_tokens")
        if self.queue_limit is not None and len(self.queue) >= self.queue_limit:
            return self._reject(req, "queue_full")
        limit = self.max_len - 1   # leave >=1 position for decode feedback
        if len(req.prompt) > limit:
            if self.on_overflow == "reject":
                return self._reject(req, "prompt_too_long")
            req.truncated = True
            req.prompt_used = list(req.prompt[:limit])
        else:
            req.prompt_used = list(req.prompt)
        req.status = "queued"
        self.queue.append(req)
        return True

    def _reject(self, req: Request, reason: str) -> bool:
        req.status = "rejected"
        req.reject_reason = reason
        req.done = True
        req.t_complete = self._clock()
        self.n_rejected += 1
        return False

    def _expire(self, now: float) -> None:
        keep = deque()
        for req in self.queue:
            if req.deadline is not None and now - req.t_arrival > req.deadline:
                self._finish(req, None, now, status="expired")
            else:
                keep.append(req)
        self.queue = keep
        for s, req in enumerate(self.active):
            if (req is not None and req.deadline is not None
                    and now - req.t_arrival > req.deadline):
                self._finish(req, s, now, status="expired")

    def _pop_next(self) -> Request:
        if self.admission == "sjf":
            best = min(range(len(self.queue)),
                       key=lambda i: len(self.queue[i].prompt_used))
            self.queue.rotate(-best)
            req = self.queue.popleft()
            self.queue.rotate(best)
            return req
        return self.queue.popleft()

    def _admit(self, now: float) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self._pop_next()
                req.status = "active"
                req.t_admitted = now
                source, hit = self._prefix_lookup(req)
                self.cache = self._reset_slot(s, source)
                self.active[s] = req
                self.pos[s] = hit
                self.pending[s] = deque(req.prompt_used[hit:])

    # -- slot recycling -----------------------------------------------------
    def _reset_slot(self, s: int, source=None):
        """Write ``source`` (default: the fresh zero state) into slot ``s``.

        Every cache leaf carries the slot axis at dim 0 — the contract the
        masked chunk step relies on.  A leaf that violates it raises instead
        of being silently skipped (the old ``hasattr(old, "at")`` guard left
        e.g. numpy leaves of a host-roundtripped cache permanently stale)."""
        src = self._fresh if source is None else source

        def put(old, new):
            old = jnp.asarray(old)   # host/numpy-restored caches still reset
            if (old.ndim < 1 or old.shape[0] != self.slots
                    or old.shape[1:] != new.shape[1:]):
                raise ValueError(
                    f"cache leaf {old.shape} does not carry the slot axis at "
                    f"dim 0 (want [{self.slots}, ...] matching {new.shape})")
            return old.at[s:s + 1].set(new.astype(old.dtype))

        return jax.tree_util.tree_map(put, self.cache, src)

    # -- prefix cache -------------------------------------------------------
    def _prefix_lookup(self, req: Request):
        """Longest cached prompt that is a strict prefix of this request's
        prompt (capped at len-1 so at least one prompt token runs through
        prefill and produces the first-token logits).  Returns
        (cache_slice | None, n_tokens_covered)."""
        if not self.prefix_cache_size:
            return None, 0
        pu = req.prompt_used
        best = None
        for key in self._prefix:
            if (len(key) <= len(pu) - 1
                    and (best is None or len(key) > len(best))
                    and list(key) == pu[:len(key)]):
                best = key
        if best is None:
            self.prefix_misses += 1
            return None, 0
        self._prefix.move_to_end(best)
        self.prefix_hits += 1
        req.prefix_hit_tokens = len(best)
        return self._prefix[best], len(best)

    def _prefix_store(self, s: int, req: Request, upto: int) -> None:
        """Snapshot slot ``s`` as the state after ``prompt_used[:upto]``.
        Called at every prefill chunk boundary (so requests that merely
        *share* a prefix — not extend a full prompt — can hit) and at prompt
        completion."""
        key = tuple(req.prompt_used[:upto])
        self._prefix[key] = jax.tree_util.tree_map(
            lambda a: a[s:s + 1], self.cache)
        self._prefix.move_to_end(key)
        while len(self._prefix) > self.prefix_cache_size:
            self._prefix.popitem(last=False)

    # -- the tick -----------------------------------------------------------
    def step(self) -> int:
        """One engine tick: expire deadlines, admit, and feed every active
        slot — up to ``prefill_chunk`` prompt tokens for slots mid-prefill,
        the previous output token for slots in decode — through ONE device
        call.  Returns the number of requests still in flight (active +
        queued)."""
        now = self._clock()
        self._expire(now)
        self._admit(now)
        C = self.prefill_chunk
        toks = np.zeros((self.slots, C), np.int32)
        pos0 = np.zeros((self.slots,), np.int32)
        nst = np.zeros((self.slots,), np.int32)
        prefilling = [False] * self.slots
        for s, req in enumerate(self.active):
            if req is None:
                continue
            pos0[s] = self.pos[s]
            if self.pending[s]:
                k = min(C, len(self.pending[s]))
                for t in range(k):
                    toks[s, t] = self.pending[s].popleft()
                nst[s] = k
                prefilling[s] = True
            else:
                toks[s, 0] = req.generated[-1]
                nst[s] = 1
        if not nst.any():
            return len(self.queue)
        self.ticks += 1
        # decode-only ticks run a 1-step call: two compiled programs total
        # (C ∈ {1, prefill_chunk}), no masked dead steps when nobody prefills
        C_live = C if any(prefilling) else 1
        self.cache, out_toks, out_scores = _chunk_step(
            self.cfg, self.params, self.cache, jnp.asarray(toks[:, :C_live]),
            jnp.asarray(pos0), jnp.asarray(nst),
            use_window=self.use_window, impl=self.impl)
        out_toks = np.asarray(out_toks)
        out_scores = np.asarray(out_scores)
        t_out = self._clock()
        for s, req in enumerate(self.active):
            if req is None or nst[s] == 0:
                continue
            k = int(nst[s])
            self.pos[s] += k
            # a per-request scoring failure finalizes THAT request with a
            # recorded failure status (its latency accounting intact) and
            # frees the slot — it must not tear down the rest of the trace
            try:
                if prefilling[s]:
                    self.tokens_prefilled += k
                    if self.prefix_cache_size:
                        self._prefix_store(s, req, int(self.pos[s]))
                    if not self.pending[s]:  # prompt consumed: first token out
                        req.score = float(out_scores[s, k - 1])
                        self._emit(s, req, int(out_toks[s, k - 1]), t_out)
                else:
                    self.tokens_decoded += 1
                    self._emit(s, req, int(out_toks[s, 0]), t_out)
            except Exception as e:
                reason = f"{type(e).__name__}: {e}"
                if req.done:    # finalized before the failure: keep the
                    req.failure_reason = reason  # outcome, record the fault
                    if self.active[s] is req:
                        self.active[s] = None
                else:
                    self._finish(req, s, self._clock(), status="failed",
                                 reason=reason)
        return sum(r is not None for r in self.active) + len(self.queue)

    def _emit(self, s: int, req: Request, tok: int, now: float) -> None:
        req.generated.append(tok)
        if req.t_first_token is None:
            req.t_first_token = now
        if (len(req.generated) >= req.max_new_tokens or tok == req.eos_id
                or self.pos[s] >= self.max_len - 1):
            self._finish(req, s, now, status="done")

    def _finish(self, req: Request, s: int | None, now: float, *,
                status: str, reason: str = "") -> None:
        req.status = status
        req.done = True
        req.t_complete = now
        if reason:
            req.failure_reason = reason
        if status == "done":
            self.n_completed += 1
        elif status == "failed":
            self.n_failed += 1
        else:
            self.n_expired += 1
        if s is not None and self.active[s] is req:
            self.active[s] = None
        if (self.metric is not None and req.score is not None
                and req.label is not None):
            # a broken metric fold must not un-serve the request: the
            # outcome stands, the fault is recorded on the request
            try:
                self.metric_state = self.metric.update(
                    self.metric_state, np.asarray([req.score], np.float32),
                    np.asarray([req.label], np.float32))
                self.n_scored += 1
            except Exception as e:
                req.failure_reason = f"metric: {type(e).__name__}: {e}"

    def streaming_metrics(self) -> dict | None:
        """The engine's streaming-metric record (None when no metric is
        attached): finalized value + resolution bound + state footprint."""
        if self.metric is None:
            return None
        return {"metric": self.metric.name,
                "backend": self.metric.backend,
                "value": self.metric.finalize(self.metric_state),
                "resolution": self.metric.resolution(self.metric_state),
                "scored": self.n_scored,
                "state_bytes": self.metric.state_bytes(self.metric_state)}

    def _partial_record(self, req: Request) -> dict:
        return {"uid": req.uid, "status": req.status,
                "generated": list(req.generated),
                "prompt_consumed": len(req.prompt_used) - (
                    len(self.pending[self.active.index(req)])
                    if req in self.active else len(req.prompt_used)),
                "score": req.score,
                "t_arrival": req.t_arrival, "t_admitted": req.t_admitted,
                "t_first_token": req.t_first_token}

    def run(self, max_ticks: int = 10_000) -> None:
        """Drive ``step`` until every request is finalized.  Raises
        ``TicksExhausted`` (not a silent return) if ticks run out with
        requests still queued or active — the exception's ``records`` list
        carries the partial per-request state of everything in flight."""
        for _ in range(max_ticks):
            if self.step() == 0:
                return
        in_flight = [r for r in self.active if r is not None] + list(self.queue)
        if in_flight:
            raise TicksExhausted(
                f"{max_ticks} ticks exhausted with "
                f"{sum(r is not None for r in self.active)} active and "
                f"{len(self.queue)} queued requests",
                records=[self._partial_record(r) for r in in_flight])
