"""Synthetic-trace load generation + latency reporting for the serving engine.

The ``serve_load`` benchmark tier (``benchmarks/run.py --only serve_load``),
``launch/serve.py``, and ``scripts/hillclimb.py --serve-exp`` all drive the
continuous-batching engine through this module:

  * ``TraceConfig``/``make_trace`` — deterministic synthetic request traces:
    ``batch`` (everything arrives at t=0 — the engine-bound comparison),
    ``poisson`` (exponential inter-arrivals at ``rate`` req/s), and
    ``bursty`` (``burst_size`` simultaneous arrivals per burst).  An optional
    ``prefix_pool`` draws shared prompt prefixes so the engine's prefix
    cache has something to hit; ``labeled=True`` plants seed-deterministic
    ground-truth labels (prompts via the ``DataConfig`` motif machinery) so
    the engine's streaming-AUC sketch measures a real signal.
  * ``run_trace`` — paces a trace against the wall clock (arrivals before
    "now" are submitted, then the engine ticks) until every request is
    finalized.
  * ``summarize`` — p50/p99 time-to-first-token, p50/p99 completion latency,
    tokens/s, and the engine's tick/token/prefix counters — the JSON
    artifact rows CI uploads.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.serving.engine import Request, ServingEngine


@dataclasses.dataclass
class TraceConfig:
    kind: str = "poisson"              # poisson | bursty | batch
    rate: float = 16.0                 # mean arrivals/s (poisson, bursty)
    n_requests: int = 32
    prompt_len: tuple[int, int] = (8, 33)   # rng.randint [lo, hi)
    max_new: tuple[int, int] = (4, 9)
    burst_size: int = 8
    prefix_pool: int = 0               # >0: share prompts' first prefix_len toks
    prefix_len: int = 12
    eos_id: int = -1
    deadline: float | None = None
    seed: int = 0
    labeled: bool = False              # plant seed-deterministic ground-truth
                                       # labels: prompts come from the
                                       # DataConfig token machinery (positives
                                       # carry motif tokens) so the engine's
                                       # streaming AUC is measured against a
                                       # real signal.  Takes precedence over
                                       # prefix_pool (labeled prompts are not
                                       # pooled).
    p_pos: float = 0.7                 # positive ratio for labeled traces
    label_signal: float = 1.5          # motif strength (DataConfig.signal)

    def __post_init__(self):
        if self.kind not in ("poisson", "bursty", "batch"):
            raise ValueError(f"unknown trace kind {self.kind!r}")
        if self.labeled and not 0.0 < self.p_pos < 1.0:
            raise ValueError(f"p_pos must be in (0, 1), got {self.p_pos}")


def make_trace(tcfg: TraceConfig, vocab_size: int) -> list[tuple[float, Request]]:
    """[(arrival_s, Request)] sorted by arrival; fully seed-deterministic, so
    the same config replayed through two engines compares like for like."""
    rng = np.random.RandomState(tcfg.seed)
    n = tcfg.n_requests
    if tcfg.kind == "batch":
        arrivals = np.zeros(n)
    elif tcfg.kind == "poisson":
        arrivals = np.cumsum(rng.exponential(1.0 / tcfg.rate, size=n))
    else:  # bursty: burst_size simultaneous arrivals, bursts at rate req/s
        arrivals = (np.arange(n) // tcfg.burst_size) * (tcfg.burst_size / tcfg.rate)
    pool = [rng.randint(0, vocab_size, size=tcfg.prefix_len).tolist()
            for _ in range(tcfg.prefix_pool)]
    labels = toks = None
    if tcfg.labeled:
        # ground truth rides the trace: Bernoulli(p_pos) labels from the
        # same seeded rng, prompts from the DataConfig token machinery
        # (positives carry motif tokens at strength label_signal) drawn
        # once at max length and truncated per request — the engine's
        # prompt score has a real signal to recover
        import jax
        import jax.numpy as jnp

        from repro.data import synthetic

        labels = (rng.uniform(size=n) < tcfg.p_pos).astype(np.float32)
        dcfg = synthetic.DataConfig(
            kind="tokens", vocab_size=vocab_size,
            seq_len=max(1, tcfg.prompt_len[1] - 1),
            signal=tcfg.label_signal, p_pos=tcfg.p_pos)
        toks = np.asarray(synthetic._draw(
            jax.random.PRNGKey(tcfg.seed), dcfg, (n,),
            jnp.asarray(labels))["tokens"])
    trace = []
    for i in range(n):
        plen = int(rng.randint(*tcfg.prompt_len))
        if toks is not None:
            prompt = toks[i, :plen].astype(int).tolist()
        elif pool:
            prefix = pool[int(rng.randint(len(pool)))]
            tail = rng.randint(0, vocab_size,
                               size=max(1, plen - len(prefix))).tolist()
            prompt = prefix + tail
        else:
            prompt = rng.randint(0, vocab_size, size=plen).tolist()
        req = Request(uid=i, prompt=prompt,
                      max_new_tokens=int(rng.randint(*tcfg.max_new)),
                      eos_id=tcfg.eos_id, deadline=tcfg.deadline,
                      label=None if labels is None else float(labels[i]))
        trace.append((float(arrivals[i]), req))
    return trace


def run_trace(engine: ServingEngine, trace: list[tuple[float, Request]], *,
              max_ticks: int = 100_000,
              on_step=None) -> tuple[list[Request], float]:
    """Pace ``trace`` against the wall clock through ``engine``.  Returns
    (requests, busy wall seconds).  Raises ``TicksExhausted``-style if the
    engine cannot drain the trace within ``max_ticks`` device ticks.
    ``on_step(engine)``, if given, runs after every engine tick — the hook
    ``launch/serve.py`` reports streaming metrics from."""
    t0 = time.monotonic()
    i, n = 0, len(trace)
    in_flight = 0
    while i < n or in_flight:
        now = time.monotonic() - t0
        while i < n and trace[i][0] <= now:
            engine.add_request(trace[i][1])
            i += 1
        in_flight = engine.step()
        if on_step is not None:
            on_step(engine)
        if in_flight == 0 and i < n:
            time.sleep(min(max(trace[i][0] - (time.monotonic() - t0), 0.0),
                           0.05))
        if engine.ticks > max_ticks:
            raise RuntimeError(
                f"trace not drained after {max_ticks} engine ticks "
                f"({i}/{n} submitted, {in_flight} in flight)")
    return [r for _, r in trace], time.monotonic() - t0


def _pct(vals, q):
    return float(np.percentile(vals, q)) if len(vals) else float("nan")


def summarize(reqs: list[Request], wall: float,
              engine: ServingEngine | None = None) -> dict:
    """The serve_load metrics record: latency percentiles + throughput +
    engine counters."""
    done = [r for r in reqs if r.status == "done"]
    ttft = [r.ttft for r in done if r.ttft is not None]
    lat = [r.latency for r in done if r.latency is not None]
    n_tok = sum(len(r.generated) for r in done)
    rec = {
        "n_requests": len(reqs),
        "completed": len(done),
        "rejected": sum(r.status == "rejected" for r in reqs),
        "expired": sum(r.status == "expired" for r in reqs),
        "failed": sum(r.status == "failed" for r in reqs),
        "truncated": sum(r.truncated for r in reqs),
        "generated_tokens": n_tok,
        "tokens_per_s": n_tok / max(wall, 1e-9),
        "wall_s": wall,
        "ttft_p50_ms": _pct(ttft, 50) * 1e3,
        "ttft_p99_ms": _pct(ttft, 99) * 1e3,
        "latency_p50_ms": _pct(lat, 50) * 1e3,
        "latency_p99_ms": _pct(lat, 99) * 1e3,
    }
    if engine is not None:
        rec.update(ticks=engine.ticks,
                   tokens_prefilled=engine.tokens_prefilled,
                   tokens_decoded=engine.tokens_decoded,
                   prefix_hits=engine.prefix_hits,
                   prefix_misses=engine.prefix_misses)
        sm = engine.streaming_metrics()
        if sm is not None:
            # e.g. streaming_auc — AUC over served traffic, next to the
            # latency percentiles
            rec["streaming_" + sm["metric"]] = sm["value"]
            rec.update(streaming_metric=sm["metric"],
                       streaming_backend=sm["backend"],
                       streaming_resolution=sm["resolution"],
                       streaming_scored=sm["scored"],
                       streaming_state_bytes=sm["state_bytes"])
    return rec


def serve_load_report(arch: str = "stablelm-1.6b", *, engine_kw: dict = None,
                      trace_kw: dict = None, seed: int = 0,
                      metric_backend: str = "") -> dict:
    """One-stop runner for hillclimb/launch: build a smoke config + params,
    serve one trace, return ``{"arch", "knobs", "trace", "metrics"}``.
    ``metric_backend`` ("exact" | "sketch") attaches a streaming-AUC metric
    to the engine — meaningful with a ``labeled`` trace, where the metrics
    record gains the ``streaming_auc`` row."""
    import jax

    from repro.configs import get_smoke_config
    from repro.models import init_params

    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    engine_kw = dict(engine_kw or {})
    engine_kw.setdefault("slots", 4)
    engine_kw.setdefault("max_len", 64)
    engine_kw.setdefault("prefill_chunk", 8)
    tcfg = TraceConfig(**(trace_kw or {}))
    metric = None
    if metric_backend:
        from repro.metrics import streaming

        metric = streaming.make_metric("auc", metric_backend)
    # warm the jit cache with a throwaway engine so the timed trace measures
    # steady-state serving, not compilation (the chunk-step jit is
    # module-level: same (cfg, shapes, chunk) reuses the compiled programs)
    warm = ServingEngine(cfg, params, **engine_kw)
    warm.add_request(Request(uid=-1, prompt=list(range(1, 12)),
                             max_new_tokens=2))
    warm.run()
    eng = ServingEngine(cfg, params, metric=metric, **engine_kw)
    reqs, wall = run_trace(eng, make_trace(tcfg, cfg.vocab_size))
    return {"arch": arch, "knobs": engine_kw,
            "trace": dataclasses.asdict(tcfg),
            "metrics": summarize(reqs, wall, eng)}
