"""Fused AUC min-max loss Pallas kernel.

One pass over the score vector produces the loss and all four gradient
components of the paper's objective F(w,a,b,α;z) (eq. 2):

    F = (1-p)(h-a)² 1[y=1] + p(h-b)² 1[y=-1]
        + 2(1+α)(p·h·1[y=-1] - (1-p)·h·1[y=1]) - p(1-p)α²

The batch axis is blocked into VMEM tiles; per-block partial reductions for
(loss, da, db, dα) land in an [n_blocks, 4] output that the wrapper sums —
one HBM read of ``h``/``y`` instead of the ~8 masked reductions XLA would
otherwise issue.  Scalar state (a, b, α, p) rides in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(scal_ref, h_ref, y_ref, dh_ref, parts_ref, *, bt: int, T: int):
    i = pl.program_id(0)
    a, b, alpha, p = (scal_ref[0], scal_ref[1], scal_ref[2], scal_ref[3])
    h = h_ref[...].astype(jnp.float32)
    pos = y_ref[...].astype(jnp.float32)
    neg = 1.0 - pos
    # mask padding rows (last block may exceed T)
    row = i * bt + jax.lax.broadcasted_iota(jnp.int32, (bt,), 0)
    live = (row < T).astype(jnp.float32)
    pos, neg = pos * live, neg * live

    da_h = h - a
    db_h = h - b
    f = ((1 - p) * da_h * da_h * pos + p * db_h * db_h * neg
         + 2 * (1 + alpha) * (p * h * neg - (1 - p) * h * pos)
         - p * (1 - p) * alpha * alpha * live)
    dh = (2 * (1 - p) * da_h * pos + 2 * p * db_h * neg
          + 2 * (1 + alpha) * (p * neg - (1 - p) * pos))
    dh_ref[...] = (dh / T).astype(dh_ref.dtype)
    parts_ref[0, 0] = jnp.sum(f) / T
    parts_ref[0, 1] = jnp.sum(-2 * (1 - p) * da_h * pos) / T
    parts_ref[0, 2] = jnp.sum(-2 * p * db_h * neg) / T
    parts_ref[0, 3] = (jnp.sum(2 * (p * h * neg - (1 - p) * h * pos)) / T
                       - 2 * p * (1 - p) * alpha * jnp.sum(live) / T)


def launch_geometry(T: int, *, block: int = 1024) -> dict:
    """Static launch geometry of one auc_loss call, shared with the
    auditor's R5 rule (analysis/audit.py).  Note ``bt`` is NOT forced to a
    multiple of 8 when T itself is small and ragged (e.g. T=12 → bt=12) —
    the kernel masks the tail rows instead."""
    bt = min(block, max(8, T))
    n = -(-T // bt)
    return {"bt": bt, "Tp": n * bt, "grid": (n,)}


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def auc_loss(h, y, a, b, alpha, p, *, block: int = 1024, interpret: bool = False):
    """Returns (loss, dh [T], da, db, dalpha) — see ref.auc_loss_ref."""
    T = h.shape[0]
    g = launch_geometry(T, block=block)
    bt, Tp = g["bt"], g["Tp"]
    (n,) = g["grid"]
    hp = jnp.pad(h.astype(jnp.float32), (0, Tp - T))
    yp = jnp.pad(y.astype(jnp.float32), (0, Tp - T))
    scal = jnp.stack([jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
                      jnp.asarray(alpha, jnp.float32), jnp.asarray(p, jnp.float32)])

    kern = functools.partial(_kernel, bt=bt, T=T)
    dh, parts = pl.pallas_call(
        kern,
        grid=g["grid"],
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((1, 4), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp,), jnp.float32),
            jax.ShapeDtypeStruct((n, 4), jnp.float32),
        ],
        interpret=interpret,
    )(scal, hp, yp)
    loss, da, db, dalpha = (parts[:, 0].sum(), parts[:, 1].sum(),
                            parts[:, 2].sum(), parts[:, 3].sum())
    return loss, dh[:T], da, db, dalpha
