"""Fused local-optimizer update (Pallas TPU): accumulator update +
preconditioned step + prox projection in ONE streaming pass.

Extends ``prox_update``'s 3-read/1-write discipline to the stateful
optimizers of ``core/optimizer.py``: the kernel reads (v, g, v0, buf) and
writes (v', buf') — 4 reads / 2 writes per element instead of the 8/3 a
separate accumulator-update + precondition + prox sequence would stream
through HBM.  All arithmetic is fp32 in-kernel regardless of the storage
dtypes; bf16 buffers are re-stored with hash-based stochastic rounding
(``kernels/ref.stochastic_round`` — the identical elementwise integer ops
run here and in the jnp oracle, so given the same accumulator bits the two
paths round identically; end-to-end the paths are separately compiled
programs whose FMA contraction may differ, pinned at fp32 noise scale in
tests).

Modes (static):
  * "momentum": buf is the momentum buffer; m = coef·m + g, d = m.
  * "precond":  buf is the fp32 accumulator cover (SM3's min-of-covers);
                ν = cover + g², d = g·rsqrt(ν + coef), ν returned fp32.

Both end with the proximal projection v' = (γ(v − η d) + η v₀)/(η + γ).

Scalars (η, γ, coef) ride SMEM so a schedule's changing η never
re-specializes the kernel; the uint32 stochastic-rounding seed rides its own
SMEM lane (it must not round-trip through f32).  Geometry mirrors
``prox_update``: flat 1-D layout, ``block``-wide tiles, grid exposed via
``launch_geometry`` for the audit's R5 static-geometry rule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref


def _kernel(mode, scal_ref, seed_ref, v_ref, g_ref, v0_ref, buf_ref,
            out_ref, buf_out_ref):
    eta = scal_ref[0]
    gamma = scal_ref[1]
    coef = scal_ref[2]
    v = v_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    v0 = v0_ref[...].astype(jnp.float32)
    buf = buf_ref[...].astype(jnp.float32)
    if mode == "momentum":
        acc = coef * buf + g
        d = acc
        new_buf = ref.stochastic_round(acc, seed_ref[0], buf_out_ref.dtype)
    else:  # "precond"
        acc = buf + g * g
        d = g * jax.lax.rsqrt(acc + coef)
        new_buf = acc.astype(buf_out_ref.dtype)
    out = (gamma * (v - eta * d) + eta * v0) / (eta + gamma)
    out_ref[...] = out.astype(out_ref.dtype)
    buf_out_ref[...] = new_buf


def launch_geometry(N: int, *, block: int = 4096) -> dict:
    """Static launch geometry (audited by rule R5): tile width ``bt``,
    padded length ``Np`` (multiple of ``bt``), 1-D ``grid``."""
    bt = min(block, max(8, N))
    n = -(-N // bt)
    return {"bt": bt, "Np": n * bt, "grid": (n,)}


@functools.partial(jax.jit, static_argnames=("mode", "block", "interpret"))
def opt_update(v, g, v0, buf, eta, gamma, coef, seed, *, mode: str,
               block: int = 4096, interpret: bool = False):
    """Flat [N] fused optimizer update; returns (new_v [N], new_buf [N])."""
    if mode not in ("momentum", "precond"):
        raise ValueError(f"unknown opt_update mode {mode!r}")
    N = v.shape[0]
    geo = launch_geometry(N, block=block)
    bt, Np = geo["bt"], geo["Np"]
    pad = lambda x: jnp.pad(x, (0, Np - N))
    scal = jnp.stack([jnp.asarray(eta, jnp.float32),
                      jnp.asarray(gamma, jnp.float32),
                      jnp.asarray(coef, jnp.float32)])
    seed = jnp.asarray(seed, jnp.uint32).reshape(1)
    tile = lambda: pl.BlockSpec((bt,), lambda i: (i,))
    out_v, out_buf = pl.pallas_call(
        functools.partial(_kernel, mode),
        grid=geo["grid"],
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.SMEM),
                  tile(), tile(), tile(), tile()],
        out_specs=(tile(), tile()),
        out_shape=(jax.ShapeDtypeStruct((Np,), v.dtype),
                   jax.ShapeDtypeStruct((Np,), buf.dtype)),
        interpret=interpret)(scal, seed, pad(v), pad(g), pad(v0), pad(buf))
    return out_v[:N], out_buf[:N]
