"""Fused CoDA proximal local-update Pallas kernel.

    v ← (γ·(v − η·g) + η·v₀) / (η + γ)

Elementwise over the flattened parameter vector, blocked into VMEM tiles.
Fusing keeps the update at 3 HBM reads + 1 write per element (v, g, v₀ → v)
instead of the 5+ round-trips of the unfused expression; η (which changes
every stage) rides in SMEM so the kernel is not re-specialized per stage.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(scal_ref, v_ref, g_ref, v0_ref, out_ref):
    eta = scal_ref[0]
    gamma = scal_ref[1]
    v = v_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    v0 = v0_ref[...].astype(jnp.float32)
    out = (gamma * (v - eta * g) + eta * v0) / (eta + gamma)
    out_ref[...] = out.astype(out_ref.dtype)


def launch_geometry(N: int, *, block: int = 4096) -> dict:
    """Static launch geometry of one prox_update call, shared with the
    auditor's R5 rule (analysis/audit.py)."""
    bt = min(block, max(8, N))
    n = -(-N // bt)
    return {"bt": bt, "Np": n * bt, "grid": (n,)}


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def prox_update(v, g, v0, eta, gamma, *, block: int = 4096, interpret: bool = False):
    """Flat arrays v, g, v0: [N].  eta may be traced; gamma static-ish scalar."""
    N = v.shape[0]
    geo = launch_geometry(N, block=block)
    bt, Np = geo["bt"], geo["Np"]
    pad = lambda x: jnp.pad(x, (0, Np - N))
    scal = jnp.stack([jnp.asarray(eta, jnp.float32), jnp.asarray(gamma, jnp.float32)])
    out = pl.pallas_call(
        _kernel,
        grid=geo["grid"],
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), v.dtype),
        interpret=interpret,
    )(scal, pad(v), pad(g), pad(v0))
    return out[:N]
