"""Flash-attention Pallas kernel for TPU (GQA, causal / sliding-window).

TPU-native design (not a CUDA port):
  * grid = (batch, q_head, S/BQ, Skv/BK); the KV axis is innermost and runs
    sequentially on a TensorCore, so the online-softmax running state
    (m, l, acc) lives in VMEM scratch across KV steps.
  * BlockSpecs tile Q/K/V into VMEM with MXU-aligned shapes (block sizes are
    multiples of 128 in the contracting/lane dims; head_dim is the lane dim).
  * GQA is expressed in the K/V index_map (q head h reads kv head h // G) —
    no materialized head repetition.
  * Blocks entirely outside the causal/window band are skipped with
    ``pl.when`` (no MXU work), the diagonal blocks are masked elementwise.

Validated against ``ref.attention_full`` in interpret mode (tests/).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int | None, bq: int, bk: int, n_kv: int,
            sm_scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = kj * bk
    # Static-shape early-out: is this KV block inside the causal/window band
    # for *any* query row of the Q block?
    needed = jnp.bool_(True)
    if causal:
        needed &= k_start <= q_start + bq - 1
    if window is not None:
        # newest query row is q_start + bq - 1; oldest allowed kv is
        # q_pos - window + 1
        needed &= k_start + bk - 1 > q_start - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale      # [BQ, hd]
        k = k_ref[0, 0].astype(jnp.float32)                 # [BK, hd]
        v = v_ref[0, 0].astype(jnp.float32)                 # [BK, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [BQ, BK]
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = jnp.ones((bq, bk), bool)
        if causal:
            valid &= k_pos <= q_pos
        if window is not None:
            valid &= k_pos > q_pos - window
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def launch_geometry(B: int, S: int, H: int, KV: int, Skv: int, hd: int, *,
                    block_q: int = 512, block_k: int = 512) -> dict:
    """Static launch geometry of one flash_attention call, shared with the
    auditor's R5 rule (analysis/audit.py).  The kernel does not pad the
    sequence axes, so S/Skv must divide by the clipped blocks — the same
    obligation the kernel asserts."""
    bq = min(block_q, S)
    bk = min(block_k, Skv)
    return {"bq": bq, "bk": bk, "G": H // KV,
            "grid": (B, H, S // bq, Skv // bk)}


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False):
    """q: [B, S, H, hd]; k/v: [B, Skv, KV, hd] -> [B, S, H, hd].

    ``window`` must be static here (the jnp fallbacks accept traced windows;
    the kernel trades that flexibility for block skipping).
    """
    B, S, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    geo = launch_geometry(B, S, H, KV, Skv, hd, block_q=block_q,
                          block_k=block_k)
    bq, bk, G = geo["bq"], geo["bk"], geo["G"]
    assert S % bq == 0 and Skv % bk == 0, (S, bq, Skv, bk)
    n_kv = Skv // bk

    # [B, heads, S, hd] layout: block over (seq) with heads/batch in the grid
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)

    grid = geo["grid"]
    kern = functools.partial(
        _kernel, causal=causal, window=window, bq=bq, bk=bk, n_kv=n_kv,
        sm_scale=hd ** -0.5)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # m  (running max)
            pltpu.VMEM((bq,), jnp.float32),      # l  (running denom)
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)
