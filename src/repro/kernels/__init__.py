"""Pallas TPU kernels for the compute hot-spots under CoDA:

  * flash_attention — dominant FLOP consumer of every backbone
  * auc_loss        — the paper's fused min-max objective + closed-form grads
  * prox_update     — CoDA's fused proximal local update (3 model copies/step)
  * moe_dispatch    — grouped expert GEMM for sorted dropless MoE dispatch
                      (the eval/decode serving hot path)

Each has a pure-jnp oracle in ``ref.py`` and a jit'd dispatcher in ``ops.py``.
"""
from repro.kernels import ops, ref  # noqa: F401
