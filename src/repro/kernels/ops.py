"""Public jit'd wrappers over the Pallas kernels with XLA fallbacks.

``impl`` semantics everywhere (one decision point: ``dispatch``):
  * "auto"   — Pallas on TPU backends; pure-jnp reference on EVERY other
               backend.  In particular a GPU backend gets the XLA-compiled
               reference, never interpret-mode Pallas — interpret mode is a
               correctness tool that runs orders of magnitude slower than
               either a real kernel or the jnp fallback, and "auto" must
               not pick it silently.
  * "ref"    — force the pure-jnp oracle.
  * "pallas" — force the kernel; off-TPU this is the explicit interpret-
               mode override (tests/debugging only).
Anything else raises — a typo'd ``impl`` must not silently fall back.

These wrappers are also what the shard_map CoDA executor
(core/coda_sharded.py) traces inside its manual-mesh region: "auto" never
selects interpret-mode Pallas off-TPU, so the per-worker local steps lower
to plain XLA on forced-host-device CPU meshes and to Mosaic kernels on real
TPU meshes, with no collective ops in either case.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.auc_loss import auc_loss as _auc_kernel
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.moe_dispatch import grouped_matmul as _grouped_kernel
from repro.kernels.opt_update import opt_update as _opt_kernel
from repro.kernels.prox_update import prox_update as _prox_kernel

# Threshold above which the jnp fallback switches from materialized scores to
# the scanned online-softmax form (memory O(S·chunk)).
_FULL_ATTN_MAX_KV = 8192


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def dispatch(impl: str) -> tuple:
    """The one backend-dispatch decision: ``(use_pallas, interpret)``.

    Covered by tests/test_kernels_dispatch.py for every (impl, backend)
    pair — the invariants are that "auto" never returns interpret mode
    (non-TPU backends go to kernels/ref.py instead) and that only the
    explicit "pallas" override may interpret off-TPU.
    """
    if impl == "pallas":
        return True, not _on_tpu()
    if impl == "ref":
        return False, False
    if impl == "auto":
        return _on_tpu(), False
    raise ValueError(f"unknown impl {impl!r} (want auto | ref | pallas)")


def attention(q, k, v, *, causal: bool = True, window=None, impl: str = "auto"):
    """GQA attention.  q: [B,S,H,hd], k/v: [B,Skv,KV,hd] -> [B,S,H,hd].

    ``window``: None / -1 = full; a Python int enables the Pallas kernel's
    block skipping; a traced scalar falls back to masked jnp (used inside
    scanned heterogeneous stacks, e.g. Hymba).
    """
    static_window = window is None or isinstance(window, int)
    if static_window and isinstance(window, int) and window < 0:
        window = None
    use_pallas, interpret = dispatch(impl)
    if use_pallas and (static_window or impl == "pallas"):
        return _flash(q, k, v, causal=causal, window=window,
                      interpret=interpret)
    if k.shape[1] <= _FULL_ATTN_MAX_KV:
        return ref.attention_full(q, k, v, causal=causal, window=window)
    return ref.attention_chunked(q, k, v, causal=causal, window=window)


def auc_loss(h, y, a, b, alpha, p, *, impl: str = "auto"):
    """Fused loss + closed-form grads of the min-max AUC objective.

    This is the kernel behind ``objective.auc_F`` (the ``auc`` entry of the
    pluggable objective registry, core/objective.py): one pass over the
    scores yields the forward value and all four partials, wired into
    autodiff via ``custom_vjp``.  New objectives that admit closed-form
    partials should follow the same seam — jnp reference in kernels/ref.py,
    Pallas kernel behind ``dispatch(impl)``.
    """
    use_pallas, interpret = dispatch(impl)
    if use_pallas:
        return _auc_kernel(h, y, a, b, alpha, p, interpret=interpret)
    return ref.auc_loss_ref(h, y, a, b, alpha, p)


def grouped_matmul(x, w, group_sizes, *, impl: str = "auto"):
    """Ragged grouped GEMM: out[i] = x[i] @ w[g(i)] for rows sorted by
    group.  x: [N, K]; w: [E, K, F]; group_sizes: [E] (sum == N).

    The compute core of the sorted dropless MoE dispatch (models/moe.py):
    "auto" runs the tile-aligned Pallas kernel on TPU and the blocked-scan
    jnp reference everywhere else — never interpret-mode Pallas (and never
    ``lax.ragged_dot``, whose only jax-0.4.x lowering densifies to
    [E, N, K]).
    """
    use_pallas, interpret = dispatch(impl)
    if use_pallas:
        return _grouped_kernel(x, w, group_sizes, interpret=interpret)
    return ref.grouped_matmul_ref(x, w, group_sizes)


def opt_update(v, g, v0, buf, eta, gamma, coef, seed, *, mode: str,
               impl: str = "auto"):
    """Fused optimizer update (the core/optimizer.py seam): accumulator
    update + preconditioned step + prox projection in one pass over a
    parameter leaf, returning ``(new_v, new_buf)``.

    ``mode="momentum"``: buf is the momentum buffer (m ← coef·m + g, d = m;
    bf16 buffers re-stored with stochastic rounding).  ``mode="precond"``:
    buf is the fp32 accumulator cover (ν = cover + g², d = g·rsqrt(ν+coef),
    ν returned fp32 for the caller's axis reductions).  The jnp oracle and
    the kernel share the rounding hash bit-exactly."""
    use_pallas, interpret = dispatch(impl)
    if use_pallas:
        nv, nb = _opt_kernel(v.reshape(-1), g.reshape(-1), v0.reshape(-1),
                             buf.reshape(-1), eta, gamma, coef, seed,
                             mode=mode, interpret=interpret)
        return nv.reshape(v.shape), nb.reshape(buf.shape)
    return ref.opt_update_ref(v, g, v0, buf, eta, gamma, coef, seed,
                              mode=mode)


def prox_update_tree(v_tree, g_tree, v0_tree, eta, gamma, *, impl: str = "auto"):
    """Apply the fused proximal update leaf-wise over parameter pytrees."""
    use_pallas, interpret = dispatch(impl)

    def upd(v, g, v0):
        if use_pallas:
            flat = _prox_kernel(v.reshape(-1), g.reshape(-1), v0.reshape(-1),
                                eta, gamma, interpret=interpret)
            return flat.reshape(v.shape)
        return ref.prox_update_ref(v, g, v0, eta, gamma)

    return jax.tree_util.tree_map(upd, v_tree, g_tree, v0_tree)
