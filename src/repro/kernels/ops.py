"""Public jit'd wrappers over the Pallas kernels with XLA fallbacks.

``impl`` semantics everywhere:
  * "auto"   — Pallas on TPU backends; pure-jnp fallback elsewhere (CPU dry
               runs and tests never trace the Mosaic path).
  * "ref"    — force the pure-jnp oracle.
  * "pallas" — force the kernel (on CPU this uses interpret mode).

These wrappers are also what the shard_map CoDA executor
(core/coda_sharded.py) traces inside its manual-mesh region: "auto" never
selects interpret-mode Pallas off-TPU, so the per-worker local steps lower
to plain XLA on forced-host-device CPU meshes and to Mosaic kernels on real
TPU meshes, with no collective ops in either case.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.auc_loss import auc_loss as _auc_kernel
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.prox_update import prox_update as _prox_kernel

# Threshold above which the jnp fallback switches from materialized scores to
# the scanned online-softmax form (memory O(S·chunk)).
_FULL_ATTN_MAX_KV = 8192


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal: bool = True, window=None, impl: str = "auto"):
    """GQA attention.  q: [B,S,H,hd], k/v: [B,Skv,KV,hd] -> [B,S,H,hd].

    ``window``: None / -1 = full; a Python int enables the Pallas kernel's
    block skipping; a traced scalar falls back to masked jnp (used inside
    scanned heterogeneous stacks, e.g. Hymba).
    """
    static_window = window is None or isinstance(window, int)
    if static_window and isinstance(window, int) and window < 0:
        window = None
    if impl == "pallas" or (impl == "auto" and _on_tpu() and static_window):
        return _flash(q, k, v, causal=causal, window=window,
                      interpret=not _on_tpu())
    if k.shape[1] <= _FULL_ATTN_MAX_KV:
        return ref.attention_full(q, k, v, causal=causal, window=window)
    return ref.attention_chunked(q, k, v, causal=causal, window=window)


def auc_loss(h, y, a, b, alpha, p, *, impl: str = "auto"):
    """Fused loss + closed-form grads of the min-max AUC objective."""
    if impl == "pallas" or (impl == "auto" and _on_tpu()):
        return _auc_kernel(h, y, a, b, alpha, p, interpret=not _on_tpu())
    return ref.auc_loss_ref(h, y, a, b, alpha, p)


def prox_update_tree(v_tree, g_tree, v0_tree, eta, gamma, *, impl: str = "auto"):
    """Apply the fused proximal update leaf-wise over parameter pytrees."""
    use_kernel = impl == "pallas" or (impl == "auto" and _on_tpu())

    def upd(v, g, v0):
        if use_kernel:
            flat = _prox_kernel(v.reshape(-1), g.reshape(-1), v0.reshape(-1),
                                eta, gamma, interpret=not _on_tpu())
            return flat.reshape(v.shape)
        return ref.prox_update_ref(v, g, v0, eta, gamma)

    return jax.tree_util.tree_map(upd, v_tree, g_tree, v0_tree)
