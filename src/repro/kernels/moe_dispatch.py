"""Grouped expert GEMM Pallas kernel for sort-based dropless MoE dispatch.

``grouped_matmul(x, w, group_sizes)`` computes ``out[i] = x[i] @ w[g(i)]``
for rows already sorted by expert id — the ragged core of the sorted
dispatch path (models/moe.py) — without materializing the [E, C, d]
capacity buffer.

Kernel strategy (MegaBlocks-style tile alignment + scalar prefetch):

  1. Each expert's row segment is padded up to a multiple of the row tile
     ``block_m`` inside a scratch layout ``xp`` so that every (bm, K) tile
     belongs to exactly ONE expert (``kernels/ref.py::grouped_layout``,
     shared with the jnp reference).  The static bound on the padded row
     count is ``round_up(N, bm) + min(E, N)·bm`` — at most one tile of
     slack per non-empty expert, negligible next to the E/top_k-fold
     padding of the capacity buffer.
  2. A per-tile expert-id table ``tile_eid [n_tiles]`` rides as a
     scalar-prefetch operand, so the WEIGHT BlockSpec's index map can
     select each tile's expert block ``w[tile_eid[t]]`` — the grid stays
     static while the weight DMA pattern follows the routing.
  3. The grid is (row tiles × ff tiles); each program issues one
     [bm, K] @ [K, bn] MXU contraction with fp32 accumulation, mirroring
     auc_loss.py's blocked one-pass structure.

Dead tiles (the alignment slack) multiply zero rows and are discarded by
the gather back to the dense [N, F] result.  Like every kernel here it is
reached only through ``kernels/ops.py::dispatch`` — "auto" uses it on TPU
and the blocked-scan jnp reference (``ref.grouped_matmul_ref``) everywhere
else; off-TPU interpret mode is the explicit ``impl="pallas"`` escape
hatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import _round_up, grouped_layout


def launch_geometry(N: int, K: int, E: int, F: int, *, block_m: int = 128,
                    block_n: int = 128) -> dict:
    """The static launch geometry of one grouped_matmul call — the single
    source of truth shared with the compiled-program auditor's R5 rule
    (analysis/audit.py): tile sizes, padded extents, and the grid, all
    derivable from shapes alone (``Np`` is the static grouped_layout
    bound, independent of the runtime group_sizes)."""
    bm = min(block_m, _round_up(max(N, 1), 8))
    bn = min(block_n, _round_up(F, 128))
    Kp = _round_up(K, 128)
    Fp = _round_up(F, bn)
    Np = _round_up(max(N, 1), bm) + min(E, max(N, 1)) * bm
    return {"bm": bm, "bn": bn, "Kp": Kp, "Fp": Fp, "Np": Np,
            "grid": (Np // bm, Fp // bn)}


def _kernel(tile_eid_ref, x_ref, w_ref, out_ref):
    del tile_eid_ref  # consumed by the weight index map
    out_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "interpret"))
def grouped_matmul(x, w, group_sizes, *, block_m: int = 128,
                   block_n: int = 128, interpret: bool = False):
    """out[i] = x[i] @ w[g(i)]; x: [N, K] sorted by group, w: [E, K, F],
    group_sizes: [E] with sum == N.  See ref.grouped_matmul_ref."""
    N, K = x.shape
    E, Kw, F = w.shape
    assert K == Kw, (K, Kw)
    g = launch_geometry(N, K, E, F, block_m=block_m, block_n=block_n)
    bm, bn, Kp, Fp = g["bm"], g["bn"], g["Kp"], g["Fp"]

    dst, tile_eid, Np = grouped_layout(group_sizes, N, bm)
    assert Np == g["Np"], (Np, g["Np"])
    xp = jnp.zeros((Np, Kp), x.dtype).at[dst].set(
        jnp.pad(x, ((0, 0), (0, Kp - K))))
    wp = jnp.pad(w, ((0, 0), (0, Kp - K), (0, Fp - F)))

    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=g["grid"],
            in_specs=[
                pl.BlockSpec((bm, Kp), lambda t, f, eid: (t, 0)),
                pl.BlockSpec((1, Kp, bn), lambda t, f, eid: (eid[t], 0, f)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda t, f, eid: (t, f)),
        ),
        out_shape=jax.ShapeDtypeStruct((Np, Fp), x.dtype),
        interpret=interpret,
    )(tile_eid, xp, wp)
    return out[dst, :F]
