"""Pure-jnp oracles for every Pallas kernel, plus the XLA fallbacks the
models use on CPU.  These define the semantics the kernels are tested
against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# attention (GQA, causal / sliding window; `window` may be a traced scalar,
# -1 or None meaning full attention)
# --------------------------------------------------------------------------
def _mask(q_pos, kv_pos, causal: bool, window):
    valid = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        valid &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        in_win = kv_pos[None, :] > (q_pos[:, None] - w)
        valid &= jnp.where(w < 0, True, in_win)
    return valid


def attention_full(q, k, v, *, causal=True, window=None):
    """q: [B, S, H, hd]; k/v: [B, Skv, KV, hd] -> [B, S, H, hd]."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bskgh,bckh->bskgc", qg, k.astype(jnp.float32))
    valid = _mask(jnp.arange(S), jnp.arange(k.shape[1]), causal, window)
    s = jnp.where(valid[None, :, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bskgc,bckh->bskgh", w, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def attention_chunked(q, k, v, *, causal=True, window=None, chunk=512):
    """Online-softmax attention, scanned over KV chunks (O(S·chunk) scores).

    Used for the long prefill shapes where materializing [S, Skv] scores is
    infeasible.  Matches ``attention_full`` to numerical tolerance.
    """
    from repro import flags
    B, S, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    C = min(flags.attn_chunk(Skv, chunk), Skv)
    assert Skv % C == 0, (Skv, C)
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32) * hd ** -0.5
    kc = jnp.moveaxis(k.reshape(B, Skv // C, C, KV, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, Skv // C, C, KV, hd), 1, 0)
    q_pos = jnp.arange(S)

    def step(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        s = jnp.einsum("bskgh,bckh->bskgc", qg, kj.astype(jnp.float32))
        kv_pos = j * C + jnp.arange(C)
        valid = _mask(q_pos, kv_pos, causal, window)
        s = jnp.where(valid[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bskgc,bckh->bskgh", p,
                                                 vj.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, S, KV, G), -1e30, jnp.float32)
    l0 = jnp.zeros((B, S, KV, G), jnp.float32)
    a0 = jnp.zeros((B, S, KV, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(Skv // C), kc, vc),
        unroll=flags.scan_unroll())
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(B, S, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# AUC min-max objective (Ying et al. 2016 reformulation) — fused loss+grads
# --------------------------------------------------------------------------
def auc_loss_ref(h, y, a, b, alpha, p):
    """Per-batch mean of F(w,a,b,α;z) and its closed-form partials.

    h: scores [T] ∈ [0,1]; y: labels [T] ∈ {0,1} (1 = positive);
    a, b, alpha, p: scalars.  Returns (loss, dh [T], da, db, dalpha).
    """
    h = h.astype(jnp.float32)
    pos = y.astype(jnp.float32)
    neg = 1.0 - pos
    T = h.shape[0]
    f = ((1 - p) * (h - a) ** 2 * pos
         + p * (h - b) ** 2 * neg
         + 2 * (1 + alpha) * (p * h * neg - (1 - p) * h * pos)
         - p * (1 - p) * alpha ** 2)
    loss = jnp.mean(f)
    dh = (2 * (1 - p) * (h - a) * pos + 2 * p * (h - b) * neg
          + 2 * (1 + alpha) * (p * neg - (1 - p) * pos)) / T
    da = jnp.sum(-2 * (1 - p) * (h - a) * pos) / T
    db = jnp.sum(-2 * p * (h - b) * neg) / T
    dalpha = jnp.sum(2 * (p * h * neg - (1 - p) * h * pos)) / T - 2 * p * (1 - p) * alpha
    return loss, dh, da, db, dalpha


# --------------------------------------------------------------------------
# ragged grouped GEMM (sort-based dropless MoE dispatch)
# --------------------------------------------------------------------------
def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def grouped_layout(group_sizes, n_rows: int, block_rows: int):
    """Row mapping for a tile-aligned grouped layout.

    Pads each group's row segment up to a multiple of ``block_rows`` so
    every row tile belongs to exactly ONE group.  Returns
    ``(dst [N], tile_gid [n_tiles], n_padded)``: sorted row i lands at
    ``dst[i]`` in the padded buffer and tile t is owned by group
    ``tile_gid[t]``.  ``n_padded`` is the static bound
    ``round_up(N, bm) + min(E, N)·bm`` — at most one tile of slack per
    NON-EMPTY group (at most min(E, N) of those), negligible next to the
    capacity path's E/top_k-fold padding.  Shared by the jnp reference
    below and the Pallas kernel (kernels/moe_dispatch.py).
    """
    E = group_sizes.shape[0]
    gs = group_sizes.astype(jnp.int32)
    inc = jnp.cumsum(gs)
    exc = inc - gs
    pc = ((gs + block_rows - 1) // block_rows) * block_rows
    pinc = jnp.cumsum(pc)
    pexc = pinc - pc
    rows = jnp.arange(n_rows, dtype=jnp.int32)
    g_row = jnp.clip(jnp.searchsorted(inc, rows, side="right"), 0, E - 1)
    dst = pexc[g_row] + (rows - exc[g_row])
    n_padded = (_round_up(max(n_rows, 1), block_rows)
                + min(E, max(n_rows, 1)) * block_rows)
    tile_starts = jnp.arange(n_padded // block_rows,
                             dtype=jnp.int32) * block_rows
    tile_gid = jnp.clip(jnp.searchsorted(pinc, tile_starts, side="right"),
                        0, E - 1).astype(jnp.int32)
    return dst, tile_gid, n_padded


def grouped_matmul_ref(x, w, group_sizes, *, block_rows: int = 128):
    """out[i] = x[i] @ w[g(i)] for rows of ``x`` sorted by group id.

    x: [N, K] with the first ``group_sizes[0]`` rows belonging to group 0,
    the next ``group_sizes[1]`` to group 1, ...; w: [E, K, F];
    group_sizes: [E] int with ``sum == N``.  Returns [N, F].

    NOT ``lax.ragged_dot``: on jax 0.4.x that primitive's only lowering is
    ragged_to_dense — it materializes a masked [E, N, K] operand, i.e.
    exactly the E-fold blow-up the sorted dispatch exists to remove.  This
    oracle instead scans over tile-aligned row blocks (``grouped_layout``),
    dynamically gathering ONE group's [K, F] weight block per tile:
    O(N·K·F) FLOPs, O(N·K + K·F) live memory, differentiable w.r.t. ``x``
    and ``w``, and vmappable with shared or stacked weights.
    """
    N, K = x.shape
    E, _, F = w.shape
    bm = min(block_rows, _round_up(max(N, 1), 8))
    dst, tile_gid, Np = grouped_layout(group_sizes, N, bm)
    xb = jnp.zeros((Np, K), x.dtype).at[dst].set(x).reshape(-1, bm, K)

    def body(_, inp):
        xt, g = inp
        return None, xt @ jax.lax.dynamic_index_in_dim(w, g, keepdims=False)

    _, yb = jax.lax.scan(body, None, (xb, tile_gid))
    return yb.reshape(Np, F)[dst]


# --------------------------------------------------------------------------
# CoDA fused proximal local update
# --------------------------------------------------------------------------
def prox_update_ref(v, g, v0, eta, gamma):
    """v ← argmin_u g·u + ‖u−v‖²/(2η) + ‖u−v0‖²/(2γ)
         = (γ(v − ηg) + ηv0) / (η + γ)."""
    eta = jnp.asarray(eta, jnp.float32)
    vf = v.astype(jnp.float32)
    out = (gamma * (vf - eta * g.astype(jnp.float32)) + eta * v0.astype(jnp.float32))
    return (out / (eta + gamma)).astype(v.dtype)


# --------------------------------------------------------------------------
# fused optimizer update (core/optimizer.py seam)
# --------------------------------------------------------------------------
def _mix_bits(x):
    """uint32 avalanche hash (xorshift-multiply finalizer).  Elementwise
    integer ops only, so the SAME function runs inside the Pallas kernel
    and in this oracle — the two paths round bit-identically."""
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def stochastic_round(x, seed, dtype):
    """fp32 → ``dtype`` with deterministic hash-based stochastic rounding.

    ``dtype=float32`` is the identity (no rounding is traced).  For bf16 the
    random low-16 bits come from hashing the value's own bit pattern with a
    per-(step, leaf) uint32 ``seed``: deterministic given (value, seed), so
    checkpoint resume replays bitwise, with no PRNG key threaded through the
    local steps.  Rounding is add-low-bits-then-truncate: unbiased, and the
    expected value of the stored buffer equals the fp32 master value."""
    if jnp.dtype(dtype) == jnp.dtype(jnp.float32):
        return x.astype(jnp.float32)
    assert jnp.dtype(dtype) == jnp.dtype(jnp.bfloat16), dtype
    xi = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    r = _mix_bits(xi ^ seed) & jnp.uint32(0x0000FFFF)
    yi = (xi + r) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(yi, jnp.float32).astype(jnp.bfloat16)


def opt_update_ref(v, g, v0, buf, eta, gamma, coef, seed, *, mode):
    """Oracle for the fused optimizer update (kernels/opt_update.py):
    accumulator update + preconditioned step + prox projection in one pass.

    mode="momentum": ``buf`` is the momentum buffer (fp32 or bf16);
        m = coef·m + g, d = m, new buffer stochastically rounded to
        ``buf.dtype``.  coef = 0 reproduces ``prox_update_ref`` bitwise.
    mode="precond": ``buf`` is the fp32 accumulator cover (e.g. SM3's
        min-of-covers); ν = cover + g², d = g·rsqrt(ν + coef), and ν comes
        back fp32 for the caller's axis reductions.
    Returns (new_v, new_buf)."""
    eta = jnp.asarray(eta, jnp.float32)
    coef = jnp.asarray(coef, jnp.float32)
    vf = v.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    bf = buf.astype(jnp.float32)
    if mode == "momentum":
        acc = coef * bf + gf
        d = acc
        new_buf = stochastic_round(acc, seed, buf.dtype)
    elif mode == "precond":
        acc = bf + gf * gf
        d = gf * jax.lax.rsqrt(acc + coef)
        new_buf = acc
    else:
        raise ValueError(f"unknown opt_update mode {mode!r}")
    out = (gamma * (vf - eta * d) + eta * v0.astype(jnp.float32))
    return (out / (eta + gamma)).astype(v.dtype), new_buf
