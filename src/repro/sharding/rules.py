"""Name-based sharding rules: parameter pytree → PartitionSpecs.

Two policies (see DESIGN.md §4):
  * "replica" — CoDA worker axis over (pod, data); tensor-parallel dims over
    "model".  Used by every arch whose replica fits a 16-chip model group.
  * "fsdp"    — giant MoE: worker axis over (pod) only; experts over "data",
    tensor-parallel dims over "model", dense-weight d_model dims additionally
    over "data" (FSDP-style), activations' batch over "data".

Every rule is divisibility-guarded: an axis that does not divide the dim is
dropped (replicated) rather than producing a lowering error — uneven vocab
sizes (92553, 256206, 32001) simply fall back to replicated embedding rows.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import coda_worker_axes

# weights whose LAST dim is the tensor-parallel output dim: [.., d_in, d_out]
_OUT_PARALLEL = {"wq", "wk", "wv", "wz", "w_gate", "w_up", "w_in", "in_proj",
                 "x_proj", "dt_proj", "lm_head"}
# weights whose FIRST trailing dim is the tensor-parallel (contracted) dim
_IN_PARALLEL = {"wo", "w_down", "w_out", "out_proj"}
# 1-d vectors laid out along the tensor-parallel dim
_VEC_PARALLEL = {"bq", "bk", "bv", "conv_b", "dt_bias", "D", "b_in"}


def _fits(dim: int, axes, mesh) -> bool:
    if axes is None:
        return False
    axes = axes if isinstance(axes, tuple) else (axes,)
    if not axes:
        return False
    n = 1
    for a in axes:
        if a not in mesh.axis_names:
            return False
        n *= mesh.shape[a]
    return dim % n == 0 and dim >= n


def _guard(shape, spec, mesh):
    out = []
    for dim, axes in zip(shape, spec):
        out.append(axes if axes is not None and _fits(dim, axes, mesh) else None)
    return out


def _trailing_rule(name: str, nd: int, policy: str, in_moe_experts: bool):
    """Spec for the trailing (per-layer, per-worker) dims of one leaf."""
    fs = "data" if policy == "fsdp" else None  # FSDP weight-shard axis
    if in_moe_experts:
        # [E, d, ff] / [E, ff, d]: experts over "data" (expert parallelism)
        ea = "data" if policy == "fsdp" else None
        if name in ("w_gate", "w_up"):
            return [ea, None, "model"]
        if name == "w_down":
            return [ea, "model", None]
        return [None] * nd
    if name == "table":          # embedding [V, d]
        return ["model", fs]
    if name == "A_log":          # [di, N]
        return ["model", None]
    if name == "conv_w":         # [K, di]
        return [None, "model"]
    if name == "r":              # sLSTM recurrent [4, H, hd, hd]
        return [None] * nd
    if name in ("projector", "enc_in"):
        return [None, "model"]
    if name in _OUT_PARALLEL and nd == 2:
        return [fs, "model"]
    if name in _IN_PARALLEL and nd == 2:
        return ["model", fs]
    if name in _VEC_PARALLEL and nd == 1:
        return ["model"]
    return [None] * nd


def param_spec(path, leaf, mesh, policy: str, *, worker_axes=()):
    """PartitionSpec for one parameter leaf given its pytree path."""
    name = ""
    keys = []
    stacked_layers = False
    for e in path:
        if hasattr(e, "key") and isinstance(e.key, str):
            keys.append(e.key)
            name = e.key
        elif hasattr(e, "idx") or hasattr(e, "index"):
            keys.append("#")
    in_layers = ("layers" in keys or "encoder" in keys)
    # stacked iff inside layers/encoder and NOT a list entry (xlstm/resnet use
    # per-layer lists whose leaves carry no leading L dim)
    stacked_layers = in_layers and "#" not in keys
    in_moe_experts = "moe" in keys and "dense" not in keys and name != "router"

    shape = leaf.shape
    spec = []
    rest = list(shape)
    if worker_axes:
        wa = tuple(a for a in worker_axes if a in mesh.axis_names)
        spec.append(wa or None)
        rest = rest[1:]
    if stacked_layers and rest:
        spec.append(None)  # the L dim
        rest = rest[1:]
    spec += _trailing_rule(name, len(rest), policy,
                           in_moe_experts and len(rest) >= 3)
    return P(*_guard(shape, spec, mesh))


def tree_shardings(tree, mesh, policy: str, *, worker_axes=()):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = [NamedSharding(mesh, param_spec(p, l, mesh, policy,
                                            worker_axes=worker_axes))
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# --------------------------------------------------------------------------
# CoDA state + batches + serving
# --------------------------------------------------------------------------
def state_shardings(state_shapes, mesh, policy: str, multi_pod: bool):
    """Shardings for every CoDA-state field.  Params-like subtrees (params,
    ref_params, the server-momentum buffer, and CODASCA's cv_/cg_ variate
    trees) get the full name-based rules; the objective's dual trees
    (duals / ref_duals / cv_duals / cg_duals — [K] scalar leaves, whatever
    fields the registered objective declares) shard their worker axis when
    it fits.  Nothing here names a dual field: subtrees route through the
    generic tree rules, plain [K] leaves through the worker-axis rule."""
    wa = coda_worker_axes(policy, multi_pod)
    out = {}
    for k, v in state_shapes.items():
        if not hasattr(v, "shape"):  # params-like / dual subtrees
            out[k] = tree_shardings(v, mesh, policy, worker_axes=wa)
        else:  # bare [K] leaves (none in the current layouts; kept generic)
            spec = P(wa) if wa and _fits(v.shape[0], tuple(wa), mesh) else P(None)
            out[k] = NamedSharding(mesh, spec)
    return out


def batch_shardings(batch_shapes, mesh, policy: str, multi_pod: bool):
    """Window batches [I, K, B, ...]: worker dim over the worker axes; under
    fsdp the per-worker batch additionally shards over "data"."""
    wa = coda_worker_axes(policy, multi_pod)
    bax = "data" if policy == "fsdp" else None

    def spec(l):
        s = [None] * len(l.shape)
        if len(l.shape) >= 2 and wa and _fits(l.shape[1], tuple(wa), mesh):
            s[1] = tuple(wa)
        if len(l.shape) >= 3 and bax and _fits(l.shape[2], (bax,), mesh):
            s[2] = bax
        return NamedSharding(mesh, P(*s))

    return jax.tree_util.tree_map(spec, batch_shapes)


def serve_shardings(tree_shapes, mesh, cache_shard: str = "heads"):
    """Serving activations/caches: batch over (pod, data) when divisible.

    KV caches [B, S, KV, hd]:
      * cache_shard="heads" — shard KV heads (or, failing divisibility,
        head_dim) over "model".  Sharding head_dim makes every attention
        contraction emit an all-reduce of [B,KV,G,S] scores — the §Perf
        decode hillclimb measures exactly that pathology.
      * cache_shard="seq"   — flash-decode style: shard the *sequence* dim
        over "model"; the cross-shard reduction is only the softmax stats
        and the [B,H,hd] partial outputs.
    """
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def spec(l):
        s = [None] * len(l.shape)
        if len(l.shape) >= 1 and axes and _fits(l.shape[0], axes, mesh):
            s[0] = axes
        if len(l.shape) == 4:
            if cache_shard == "seq" and _fits(l.shape[1], ("model",), mesh):
                s[1] = "model"
            elif _fits(l.shape[2], ("model",), mesh):
                s[2] = "model"
            elif _fits(l.shape[3], ("model",), mesh):
                s[3] = "model"
        if len(l.shape) == 3 and cache_shard == "seq" \
                and _fits(l.shape[1], ("model",), mesh):
            s[1] = "model"  # per-slot scale tensors [B, S, KV]
        return NamedSharding(mesh, P(*s))

    return jax.tree_util.tree_map(spec, tree_shapes)


def policy_for(arch_name: str) -> str:
    """Giant MoEs cannot give every 16-chip group a replica (DESIGN.md §4)."""
    return "fsdp" if arch_name in ("arctic-480b", "dbrx-132b") else "replica"


# --------------------------------------------------------------------------
# shard_map executor specs (core/coda_sharded.py)
# --------------------------------------------------------------------------
def worker_partition(mesh, policy: str, K: int):
    """The mesh axes the CoDA worker axis is *actually* laid over.

    Applies the same divisibility guard as the parameter rules: when K does
    not divide the worker axes' extent (e.g. K=1 on an 8-way data axis —
    the PPD-SG degenerate case) the worker axis is replicated instead, which
    keeps the manual executor correct (redundant compute, zero collectives)
    rather than failing to lower.
    """
    wa = coda_worker_axes(policy, multi_pod="pod" in mesh.axis_names)
    wa = tuple(a for a in wa if a in mesh.axis_names)
    return wa if wa and _fits(K, wa, mesh) else ()


def shardmap_state_specs(state, mesh, policy: str):
    """shard_map in/out specs for the CoDA state: leading worker dim over
    ``worker_partition``, all trailing dims replicated.  (Within-worker
    tensor/FSDP parallelism inside the manual region is the multi-host
    follow-on tracked in ROADMAP.md — jax 0.4.x cannot nest auto-GSPMD
    subgroups under a manual worker axis.)"""
    K = jax.tree_util.tree_leaves(state)[0].shape[0]
    wa = worker_partition(mesh, policy, K)
    lead = wa if wa else None
    return jax.tree_util.tree_map(
        lambda l: P(lead, *([None] * (l.ndim - 1))), state)


def shardmap_batch_specs(batch, mesh, policy: str, K: int, *,
                         worker_dim: int = 1):
    """Specs for batches: window batches [I, K, B, ...] (worker_dim=1) and
    stage-end α batches [K, m, ...] (worker_dim=0)."""
    wa = worker_partition(mesh, policy, K)
    lead = wa if wa else None

    def spec(l):
        s = [None] * l.ndim
        s[worker_dim] = lead
        return P(*s)

    return jax.tree_util.tree_map(spec, batch)
