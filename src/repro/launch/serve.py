"""Serving launcher: runs the continuous-batching engine on a reduced config
(CPU) with synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mcfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, mcfg)
    eng = ServingEngine(mcfg, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.RandomState(args.seed)
    reqs = []
    for i in range(args.requests):
        prompt = rng.randint(0, mcfg.vocab_size, size=rng.randint(4, 17)).tolist()
        req = Request(uid=i, prompt=prompt, max_new_tokens=args.max_new)
        reqs.append(req)
        eng.add_request(req)

    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in reqs)
    for r in reqs[:4]:
        print(f"req {r.uid}: prompt[{len(r.prompt)}] -> {r.generated}")
    print(f"served {len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / max(dt, 1e-9):.1f} tok/s, slots={args.slots})")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
