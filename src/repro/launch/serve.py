"""Serving launcher: drives the continuous-batching engine on a reduced
config (CPU) with a synthetic request trace and prints the latency summary.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --trace poisson --rate 32 --requests 16 --prefix-cache 8

Batching knobs (--slots, --prefill-chunk, --admission, --queue-limit,
--prefix-cache) mirror ``ServingEngine``'s; trace knobs (--trace, --rate,
--deadline) mirror ``loadgen.TraceConfig``'s.  ``scripts/hillclimb.py
--serve-exp`` sweeps the same knobs into JSON artifacts.

--labeled plants seed-deterministic ground-truth labels on the trace and
attaches a streaming metric (shared flags with launch/train.py via
repro.metrics.report: --metrics {exact,sketch}, --metric-interval N
finished requests, --metric-bins) so the engine reports AUC over served
traffic next to the latency percentiles:

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --trace batch --requests 24 --labeled --metrics sketch \
      --metric-interval 8
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_smoke_config
from repro.metrics import report as metric_report
from repro.metrics import streaming
from repro.models import init_params
from repro.serving import ServingEngine
from repro.serving import loadgen as LG


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--queue-limit", type=int, default=None)
    ap.add_argument("--admission", default="fifo", choices=["fifo", "sjf"])
    ap.add_argument("--prefix-cache", type=int, default=0,
                    help="LRU entries for the prompt-prefix cache (0 = off)")
    ap.add_argument("--trace", default="batch",
                    choices=["batch", "poisson", "bursty"])
    ap.add_argument("--rate", type=float, default=16.0,
                    help="mean arrivals/s for poisson/bursty traces")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline in seconds after arrival")
    ap.add_argument("--labeled", action="store_true",
                    help="plant ground-truth labels on the trace and report "
                         "streaming AUC over served traffic")
    ap.add_argument("--p-pos", type=float, default=0.7,
                    help="positive ratio for --labeled traces")
    ap.add_argument("--seed", type=int, default=0)
    metric_report.add_metric_args(ap)
    args = ap.parse_args()

    mcfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), mcfg)
    met = rep = None
    if args.labeled:
        met = streaming.make_metric("auc", args.metrics,
                                    bins=args.metric_bins)
        rep = metric_report.IntervalReporter(met,
                                             interval=args.metric_interval,
                                             label="serve")
    eng = ServingEngine(mcfg, params, slots=args.slots, max_len=args.max_len,
                        prefill_chunk=args.prefill_chunk,
                        queue_limit=args.queue_limit,
                        admission=args.admission,
                        prefix_cache_size=args.prefix_cache,
                        metric=met)
    tcfg = LG.TraceConfig(kind=args.trace, rate=args.rate,
                          n_requests=args.requests,
                          max_new=(args.max_new, args.max_new + 1),
                          deadline=args.deadline, seed=args.seed,
                          labeled=args.labeled, p_pos=args.p_pos)
    on_step = None
    if rep is not None and rep.interval > 0:
        # ticks are finished *scored* requests; state is already on the
        # engine, so the lazy state_fn is just an attribute read
        on_step = lambda e: rep.tick(e.n_scored, lambda: e.metric_state)
    reqs, wall = LG.run_trace(eng, LG.make_trace(tcfg, mcfg.vocab_size),
                              on_step=on_step)
    for r in reqs[:4]:
        print(f"req {r.uid}: prompt[{len(r.prompt)}] {r.status} "
              f"-> {r.generated}")
    m = LG.summarize(reqs, wall, eng)
    print(f"served {m['completed']}/{m['n_requests']} requests "
          f"({m['rejected']} rejected, {m['expired']} expired), "
          f"{m['generated_tokens']} tokens in {m['wall_s']:.2f}s "
          f"({m['tokens_per_s']:.1f} tok/s, slots={args.slots}, "
          f"chunk={args.prefill_chunk})")
    print(f"ttft p50/p99: {m['ttft_p50_ms']:.1f}/{m['ttft_p99_ms']:.1f} ms; "
          f"latency p50/p99: {m['latency_p50_ms']:.1f}/"
          f"{m['latency_p99_ms']:.1f} ms; ticks={m['ticks']}")
    if rep is not None:
        rep.report(f"final ({eng.n_scored} scored)", eng.metric_state,
                   n_seen=eng.n_scored)
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
