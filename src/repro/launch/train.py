"""CoDA training launcher.

CPU-scale end-to-end run (reduced configs) or the production mesh layout.

Executor selection (--executor):
  * vmap       — single-device oracle: the K-worker axis is a batched array
                 axis; exact semantics, nothing crosses a wire.
  * shard_map  — production path (core/coda_sharded.py): workers laid over
                 real mesh devices, I local steps collective-free, one
                 bucketed all-reduce per window.  On a CPU host pass
                 --force-host-devices N to split the host into N XLA
                 devices (the flag must take effect before jax initialises,
                 which is why it is a CLI arg and not ambient config).

Algorithm selection (--algorithm):
  * coda     — the paper's algorithm (assumes homogeneous shards).
  * codasca  — control-variate corrected CoDA (core/codasca.py) for
               heterogeneous shards; same ONE all-reduce per window, 2x the
               payload.  Pair with --dirichlet-alpha to make the shards
               actually heterogeneous: Dirichlet(α) label skew, small α =
               extreme skew, unset/inf = the paper's IID split.

Objective selection (--objective, core/objective.py registry):
  * auc      — the paper's min-max AUC (duals a, b, α).
  * pauc_dro — one-way partial AUC at FPR ≤ --pauc-beta as a KL-DRO
               min-max: negatives are softmax-reweighted by hardness with
               the DRO temperature riding the dual state.  The run summary
               reports pAUC@β next to full AUC.
Both ship their dual tree in the same one-bucket window all-reduce; the
payload accounting adapts to the tree automatically.

--server-momentum β applies the CODASCA-style server momentum buffer to
every window's averaged iterate (replicated server state, zero extra wire
bytes; 0 = off).

Metric reporting (shared flags with launch/serve.py via
repro.metrics.report): --metrics exact evaluates the held-out test split at
every --metric-interval windows through the exact Metric backend;
--metrics sketch turns on the in-training streaming sketch
(CoDAConfig.stream_bins = --metric-bins): every local step histograms the
scores the loss already computed, the per-window merge rides the existing
window all-reduce as 2·bins·4 extra fp32 bytes, and the report line shows
the training-stream AUC with its resolution bound.

Fault tolerance (--participation / --straggler-prob / --max-staleness /
--fault-seed): a seed-deterministic FaultPlan (core/faults.py) drops a
fraction of per-window contributions and delays stragglers; the window
all-reduce switches to the masked participant mean (still ONE collective,
payload + a tiny weight lane).  --ckpt-every N + --ckpt-dir save
crash-recovery checkpoints at window boundaries; --resume restarts
bitwise-identically to the uninterrupted run.

Overlapped averaging (--overlap, shard_map only): the window all-reduce is
rescheduled as C = --overlap-chunks ppermute ring chains per dtype bucket
inside a fused two-window step, so the first window's wire time hides under
the second window's local compute.  Same mean, same logical comm bytes —
the run summary splits them into overlapped vs exposed.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
      --workers 4 --stages 2 --t0 30 --interval 8
  PYTHONPATH=src python -m repro.launch.train --arch mlp --workers 8 \
      --executor shard_map --force-host-devices 8 --overlap \
      --overlap-chunks 4 --stages 2 --interval 4
  PYTHONPATH=src python -m repro.launch.train --arch mlp --workers 8 \
      --stages 3 --t0 100 --interval 16 --p-pos 0.71 \
      --executor shard_map --force-host-devices 8 --compress int8
  PYTHONPATH=src python -m repro.launch.train --arch mlp --workers 8 \
      --algorithm codasca --dirichlet-alpha 0.1 --stages 3 --interval 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import get_config, get_smoke_config
from repro.configs.base import mlp_config
from repro.core import coda, objective, optimizer, schedules
from repro.data import DataConfig, ShardedDataset
from repro.launch import mesh as mesh_mod
from repro.metrics import report as metric_report
from repro.metrics import streaming


def data_config_for(mcfg, p_pos: float) -> DataConfig:
    if mcfg.family == "mlp":
        return DataConfig(kind="features", p_pos=p_pos, n_features=mcfg.n_features)
    if mcfg.family == "cnn":
        return DataConfig(kind="images", p_pos=p_pos, image_hw=32)
    return DataConfig(kind="tokens", p_pos=p_pos, vocab_size=mcfg.vocab_size,
                      seq_len=64, d_model=mcfg.d_model)


def make_batch_adapters(mcfg, ds: ShardedDataset, key):
    """Wrap the dataset so modality stubs (patches/frames) are attached."""

    def adapt(b):
        if mcfg.family == "vlm":
            lead = b["tokens"].shape[:-1]
            b = dict(b)
            b["patches"] = jax.random.normal(
                key, lead + (mcfg.n_patches, mcfg.d_model))
            b["tokens"] = b["tokens"][..., :max(1, b["tokens"].shape[-1] - mcfg.n_patches)]
        elif mcfg.family == "audio":
            lead = b["tokens"].shape[:-1]
            S = b["tokens"].shape[-1]
            b = dict(b)
            b["frames"] = jax.random.normal(key, lead + (S, mcfg.d_model))
            b["tokens"] = b["tokens"][..., :max(1, S // mcfg.decoder_fraction)]
        return b

    return adapt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mlp")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--stages", type=int, default=3)
    ap.add_argument("--t0", type=int, default=60)
    ap.add_argument("--eta0", type=float, default=0.5)
    ap.add_argument("--interval", type=int, default=8, help="I (0 = Thm-1 rule)")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--p-pos", type=float, default=0.71)
    ap.add_argument("--n-data", type=int, default=8192)
    ap.add_argument("--algorithm", choices=["coda", "codasca"], default="coda",
                    help="codasca = control-variate corrected local steps "
                         "for heterogeneous (non-IID) shards")
    ap.add_argument("--objective", choices=list(objective.names()),
                    default="auc",
                    help="which min-max objective to solve "
                         "(core/objective.py registry)")
    ap.add_argument("--pauc-beta", type=float, default=0.3,
                    help="FPR budget β for --objective pauc_dro")
    ap.add_argument("--server-momentum", type=float, default=0.0,
                    help="β for server momentum on the averaged iterate "
                         "(0 = off; the buffer stays server-side, no extra "
                         "wire bytes)")
    ap.add_argument("--optimizer", choices=list(optimizer.names()),
                    default="sgd",
                    help="local primal optimizer (core/optimizer.py "
                         "registry); preconditioning is strictly LOCAL — "
                         "the window all-reduce still carries only the "
                         "model payload, never optimizer state")
    ap.add_argument("--opt-dtype", choices=["fp32", "bf16"], default="fp32",
                    help="storage dtype for optimizer accumulators; bf16 "
                         "halves optimizer-state bytes (fp32 master math "
                         "in-kernel, stochastic-rounded stores)")
    ap.add_argument("--opt-beta", type=float, default=0.9,
                    help="momentum coefficient (--optimizer momentum)")
    ap.add_argument("--opt-eps", type=float, default=1e-6,
                    help="preconditioner damping (sm3 / shampoo_blocked)")
    ap.add_argument("--shampoo-block", type=int, default=32,
                    help="block size b for shampoo_blocked's per-block "
                         "[b, b] second-moment statistics")
    ap.add_argument("--precond-every", type=int, default=1,
                    help="recompute the shampoo inverse-root preconditioner "
                         "every N local steps (stale preconditioner "
                         "in between — cheaper, usually harmless)")
    ap.add_argument("--dirichlet-alpha", type=float, default=float("inf"),
                    help="Dirichlet(α) label-skew across the K shards "
                         "(inf = IID even split, the paper's setting)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="per-window probability a worker's contribution "
                         "makes the merge (< 1 turns on the fault-injection "
                         "harness: masked participant-mean averaging, same "
                         "ONE all-reduce per window)")
    ap.add_argument("--straggler-prob", type=float, default=0.0,
                    help="per-window probability a worker starts straggling "
                         "(its contributions arrive --straggler-windows "
                         "windows late)")
    ap.add_argument("--straggler-windows", type=int, default=1,
                    help="how many windows a straggler's contribution lags")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="merge straggler contributions up to this many "
                         "windows late (staleness-discounted weight); later "
                         "arrivals are dropped and the worker re-synced")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the deterministic fault schedule "
                         "(core/faults.FaultPlan — replayable)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="with --ckpt-dir: save state + loop counters every "
                         "N windows (crash-recovery checkpoints; resume "
                         "with --resume)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt-dir "
                         "(bitwise-identical to the uninterrupted run)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--executor", choices=["vmap", "shard_map"],
                    default="vmap",
                    help="vmap = single-device oracle; shard_map = workers "
                         "on real mesh devices with one all-reduce/window")
    ap.add_argument("--policy", choices=["replica", "fsdp"], default="replica",
                    help="worker placement: replica = workers over the data "
                         "axis; fsdp = workers over the pod axis only")
    ap.add_argument("--compress", choices=["", "int8"], default="",
                    help="int8 = compressed averaging: only the int8 payload "
                         "+ per-tensor fp32 scales cross the wire")
    ap.add_argument("--overlap", action="store_true",
                    help="overlap the window averaging with the next "
                         "window's compute: the sharded executor fuses "
                         "window PAIRS and lowers each averaging as chunked "
                         "ppermute rings instead of one blocking all-reduce "
                         "(requires --executor shard_map; same mean, same "
                         "comm bytes, first-of-pair latency hidden)")
    ap.add_argument("--overlap-chunks", type=int, default=4,
                    help="ring chains per dtype bucket under --overlap "
                         "(more chunks = finer overlap granularity, more "
                         "ppermute hops)")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    help="split the CPU host into N XLA devices (needed for "
                         "--executor shard_map on CPU; must be a fresh "
                         "process — jax locks the device count on first use)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 3-axis (pod, data, model) mesh layout")
    metric_report.add_metric_args(ap)
    args = ap.parse_args()

    if args.force_host_devices:
        mesh_mod.force_host_device_count(args.force_host_devices)

    if args.arch == "mlp":
        mcfg = mlp_config()
    elif args.smoke:
        mcfg = get_smoke_config(args.arch)
    else:
        mcfg = get_config(args.arch)

    key = jax.random.PRNGKey(args.seed)
    dcfg = data_config_for(mcfg, args.p_pos)
    ds = ShardedDataset(key, dcfg, args.n_data, args.workers,
                        target_p=args.p_pos,
                        dirichlet_alpha=args.dirichlet_alpha)
    adapt = make_batch_adapters(mcfg, ds, key)
    print(f"dataset: n={ds.n} p_pos={ds.p_pos:.3f} workers={args.workers}")
    if np.isfinite(args.dirichlet_alpha):
        pp = np.array(ds.shard_p_pos)
        print(f"non-IID shards (Dirichlet α={args.dirichlet_alpha:g}): "
              f"sizes={ds.shard_sizes} shard p_pos "
              f"[{pp.min():.2f}, {pp.max():.2f}] (std {pp.std():.3f})")

    if args.overlap and args.executor != "shard_map":
        raise SystemExit("--overlap needs --executor shard_map (the vmap "
                         "oracle has no wire to overlap)")
    ccfg = coda.CoDAConfig(n_workers=args.workers, p_pos=ds.p_pos,
                           avg_compress=args.compress,
                           algorithm=args.algorithm,
                           objective=args.objective,
                           pauc_beta=args.pauc_beta,
                           server_momentum=args.server_momentum,
                           overlap_chunks=args.overlap_chunks
                           if args.overlap else 0,
                           stream_bins=args.metric_bins
                           if args.metrics == "sketch" else 0,
                           participation=args.participation,
                           straggler_prob=args.straggler_prob,
                           straggler_windows=args.straggler_windows,
                           max_staleness=args.max_staleness,
                           fault_seed=args.fault_seed,
                           optimizer=args.optimizer,
                           opt_dtype=jnp.bfloat16
                           if args.opt_dtype == "bf16" else jnp.float32,
                           opt_beta=args.opt_beta,
                           opt_eps=args.opt_eps,
                           shampoo_block=args.shampoo_block,
                           precond_every=args.precond_every)
    if args.optimizer != "sgd":
        sts = jax.eval_shape(lambda k: coda.init_state(k, mcfg, ccfg), key)
        print(f"optimizer: {args.optimizer} ({args.opt_dtype}) "
              f"state={coda.opt_state_bytes(sts):,} B/worker "
              f"(local only — never on the wire)")
    if ccfg.faults_enabled:
        print(f"fault injection: participation={args.participation:g} "
              f"straggler_prob={args.straggler_prob:g} "
              f"(lag {args.straggler_windows}, max_staleness "
              f"{args.max_staleness}) seed={args.fault_seed}")
    sched = schedules.ScheduleConfig(n_workers=args.workers, eta0=args.eta0,
                                     T0=args.t0, I0=args.interval,
                                     p_pos=ds.p_pos)

    mesh = None
    if args.executor == "shard_map":
        mesh = mesh_mod.make_worker_mesh(multi_pod=args.multi_pod)
        print(f"mesh: {dict(mesh.shape)} policy={args.policy} "
              f"devices={len(mesh.devices.flat)}")

    test = adapt(ds.full(2048))
    obj = objective.for_config(ccfg)

    def test_scores(state):
        from repro.models import model as M
        params0 = jax.tree_util.tree_map(lambda x: x[0], state["params"])
        inputs = {k: v for k, v in test.items() if k != "labels"}
        h, _ = M.score(mcfg, params0, inputs)
        return h

    # the eval hook reports through the shared metric plumbing: sketch mode
    # lifts the in-training streaming accumulator (state["sk_acc"], merged on
    # the window wire) to the host; exact mode scores the held-out split
    met = obj.metric(args.metrics, bins=args.metric_bins,
                     lo=ccfg.stream_range[0], hi=ccfg.stream_range[1]) \
        if args.metrics == "sketch" else obj.metric("exact")
    rep = metric_report.IntervalReporter(met, interval=args.metric_interval,
                                         label="train")
    n_evals = [0]

    def eval_fn(state) -> float:
        n_evals[0] += 1
        if args.metrics == "sketch":
            sk = streaming.sketch_from_rows(state["sk_acc"],
                                            *ccfg.stream_range)
            out = rep.report(f"eval {n_evals[0]}", sk, n_seen=int(sk.count))
            if "sk_loc" in state:
                # per-worker AUC skew off the local (never-averaged) sketch
                # lanes — zero extra wire bytes
                print(metric_report.worker_skew_line(
                    "train", f"eval {n_evals[0]}", met, state["sk_loc"],
                    *ccfg.stream_range))
            return out
        st = met.update(met.init(), test_scores(state), test["labels"])
        return rep.report(f"eval {n_evals[0]}", st,
                          n_seen=int(np.asarray(test["labels"]).size))

    t0 = time.time()
    res = coda.fit(
        key, mcfg, ccfg, sched, args.stages,
        sample_window=lambda k, i: adapt(ds.sample_window(k, i, args.batch)),
        sample_alpha_batch=lambda k, m: adapt(ds.sample_alpha_batch(k, m)),
        eval_every=args.metric_interval,
        eval_fn=eval_fn if args.metric_interval else None,
        executor=args.executor, mesh=mesh, policy=args.policy,
        ckpt_dir=args.ckpt_dir if args.ckpt_every else "",
        ckpt_every=args.ckpt_every, resume=args.resume)
    dt = time.time() - t0
    h_test = test_scores(res.state)
    auc = streaming.make_metric("auc", "exact").compute(h_test, test["labels"])
    extra = ""
    if obj.metric_name != "auc":
        m = obj.metric("exact").compute(h_test, test["labels"])
        extra = f", test {obj.metric_name}@{args.pauc_beta:g}={m:.4f}"
    print(f"done: {res.iterations} iters, {res.comm_rounds} comm rounds, "
          f"{dt:.1f}s, test AUC={auc:.4f}{extra}")
    if args.metrics == "sketch":
        sk = streaming.sketch_from_rows(res.state["sk_acc"],
                                        *ccfg.stream_range)
        rep.report("final train-stream", sk, n_seen=int(sk.count))
        if "sk_loc" in res.state:
            print(metric_report.worker_skew_line(
                "train", "final", met, res.state["sk_loc"],
                *ccfg.stream_range))
    compress = args.compress or None
    total = coda.comm_bytes(schedules.stages(sched, args.stages), res.state,
                            compress,
                            stage_bytes=coda.stage_payload_bytes(ccfg))
    print(f"bytes/round/worker={coda.window_payload_bytes(res.state, compress):,} "
          f"(schedule total {total:,})")
    if args.overlap:
        print(f"overlap: {res.overlapped_bytes:,} bytes hidden under "
              f"next-window compute, {res.exposed_bytes:,} exposed "
              f"(chunks={args.overlap_chunks})")
    if args.ckpt_dir and not args.ckpt_every:
        # final-state export only; --ckpt-every owns the directory for the
        # crash-recovery window checkpoints (their metadata carries the
        # loop counters --resume restarts from)
        path = checkpoint.save(args.ckpt_dir, res.iterations, res.state,
                               {"auc": auc, "arch": mcfg.name})
        print("checkpoint:", path)


if __name__ == "__main__":
    main()
