"""Mesh construction.  Everything is a function — importing this module never
touches jax device state (jax locks the device count on first backend init,
and the dry-run needs to set XLA_FLAGS before that happens).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The production target: one v5e-class pod = a (16, 16) slice with axes
    (data, model); two pods add a leading "pod" axis over DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh with the same axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def coda_worker_axes(policy: str, multi_pod: bool):
    """Which mesh axes the CoDA worker (replica) axis is sharded over.

    * replica — every worker is one `model`-axis group: K = pod × data.
    * fsdp    — the giant-MoE policy: a worker spans (data × model); only the
      pod axis carries workers (K = 2 multi-pod, K = 1 single-pod = PPD-SG).
    """
    if policy == "replica":
        return ("pod", "data") if multi_pod else ("data",)
    if policy == "fsdp":
        return ("pod",) if multi_pod else ()
    raise ValueError(policy)


def n_workers(mesh, policy: str) -> int:
    axes = coda_worker_axes(policy, multi_pod="pod" in mesh.axis_names)
    k = 1
    for a in axes:
        k *= mesh.shape[a]
    return max(k, 1)
