"""Mesh construction.  Everything is a function — importing this module never
touches jax device state (jax locks the device count on first backend init,
and the dry-run needs to set XLA_FLAGS before that happens).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The production target: one v5e-class pod = a (16, 16) slice with axes
    (data, model); two pods add a leading "pod" axis over DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh with the same axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_worker_mesh(n_devices: int = 0, *, multi_pod: bool = False):
    """A mesh for the shard_map CoDA executor on whatever devices exist.

    All available devices (or the first ``n_devices``) go to the worker-
    carrying axes: ``(data, model=1)`` single-pod, ``(2, n/2, 1)`` multi-pod.
    On CPU hosts, set ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    (or use ``force_host_device_count``) *before* jax initialises its
    backend to get N > 1.
    """
    n = n_devices or len(jax.devices())
    if multi_pod:
        if n % 2:
            raise ValueError(f"multi_pod needs an even device count, got {n}")
        return jax.make_mesh((2, n // 2, 1), ("pod", "data", "model"))
    return jax.make_mesh((n, 1), ("data", "model"))


def force_host_device_count(n: int) -> None:
    """Ask XLA for ``n`` host (CPU) devices.  Must run before the first
    backend touch — jax locks the device count on first init, so drivers
    call this at the top of main() (see launch/train.py, benchmarks/run.py).
    """
    import os
    import re
    import sys

    flags = os.environ.get("XLA_FLAGS", "")
    flag = f"--xla_force_host_platform_device_count={n}"
    if "--xla_force_host_platform_device_count" in flags:
        new = re.sub(r"--xla_force_host_platform_device_count=\d+", flag,
                     flags)
        if new != flags:
            print(f"warning: XLA_FLAGS already forced a host device count; "
                  f"overriding to {n}", file=sys.stderr)
            os.environ["XLA_FLAGS"] = new
    else:
        os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()


def abstract_mesh(shape, axis_names):
    """Version-portable AbstractMesh: jax 0.4.x takes a tuple of
    (name, size) pairs, 0.5+ takes (axis_sizes, axis_names)."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(zip(axis_names, shape)))   # 0.4.x
    except TypeError:
        return AbstractMesh(tuple(shape), tuple(axis_names))  # 0.5+


def coda_worker_axes(policy: str, multi_pod: bool):
    """Which mesh axes the CoDA worker (replica) axis is sharded over.

    * replica — every worker is one `model`-axis group: K = pod × data.
    * fsdp    — the giant-MoE policy: a worker spans (data × model); only the
      pod axis carries workers (K = 2 multi-pod, K = 1 single-pod = PPD-SG).
    """
    if policy == "replica":
        return ("pod", "data") if multi_pod else ("data",)
    if policy == "fsdp":
        return ("pod",) if multi_pod else ()
    raise ValueError(policy)


def n_workers(mesh, policy: str) -> int:
    axes = coda_worker_axes(policy, multi_pod="pod" in mesh.axis_names)
    k = 1
    for a in axes:
        k *= mesh.shape[a]
    return max(k, 1)
