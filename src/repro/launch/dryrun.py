import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count on
# first backend init.  512 placeholder host devices back both the (16,16)
# single-pod mesh and the (2,16,16) multi-pod mesh.  Only the dry-run does
# this; tests/benches see 1 device.

"""Multi-pod dry-run: AOT ``.lower().compile()`` of every
(architecture × input shape × mesh) combination against the production mesh,
recording memory_analysis / cost_analysis / collective bytes that feed the
roofline model documented in docs/analysis.md.

  train_4k     -> CoDA window_step (local primal-dual step + averaging)
  prefill_32k  -> prefill_step (forward + stacked KV-cache emission)
  decode_32k   -> serve_step (1 new token against a seq_len cache)
  long_500k    -> serve_step (sub-quadratic archs; dense via sliding window;
                  skipped for seamless-m4t — DESIGN.md §Arch-applicability)

FLOP-accounting methodology:
XLA's cost_analysis counts a while-loop body ONCE regardless of trip count,
so with DRYRUN_UNROLL every structural scan (layer stack, chunked attention,
mLSTM chunk loop) is unrolled before lowering — the full lowering's costs are
honest as-is.  (The optional REPRO_DRYRUN_DELTAS=1 L=1/L=2 probe lowerings
cross-check that: honest ≈ F(L=1) + (L-1)·(F(L=2)−F(L=1)).)  The only scan
never unrolled is the sequential sLSTM time loop (S steps); its analytic
per-step correction is added explicitly (slstm_flop_correction).

The CoDA averaging collective is isolated with an averaging-only lowering so
the roofline can report collective bytes per iteration as
``internal + avg / I`` for any communication interval I — which is exactly
the knob the paper's Theorem 1 trades off.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --both-meshes
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import flags
flags.DRYRUN_UNROLL = True  # unroll inner data scans for honest costs

from repro.analysis import hlo as H
from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, input_specs
from repro.core import coda
from repro.launch import mesh as MESH
from repro.serving import decode as D
from repro.sharding import rules as R

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")


def slstm_flop_correction(mcfg, shape, n_workers: int = 1) -> float:
    """The strictly-sequential sLSTM time scan is never unrolled; XLA counts
    its body once.  Add the analytic per-step FLOPs × (S-1) for the per-head
    recurrent einsum (the dominant in-scan term): 2 · B · 4 · d · hd."""
    if mcfg.family != "ssm" or mcfg.slstm_every <= 0:
        return 0.0
    if shape.kind == "decode":
        return 0.0  # decode is a single step — fully counted
    n_slstm = sum(1 for i in range(mcfg.n_layers)
                  if i % mcfg.slstm_every == mcfg.slstm_every - 1)
    B = shape.global_batch // max(n_workers, 1)
    hd = mcfg.d_model // mcfg.n_heads
    per_step = 2.0 * B * 4 * mcfg.d_model * hd
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd ≈ 3× fwd
    return per_step * (shape.seq_len - 1) * n_slstm * mult


def is_skipped(arch: str, shape_name: str) -> str:
    if shape_name == "long_500k" and arch == "seamless-m4t-medium":
        return ("quadratic enc/cross attention over 512k frames; no published "
                "sub-quadratic variant for this arch (DESIGN.md)")
    return ""


def _spec_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(tree))


def _with_layers(mcfg, n):
    kw = {"n_layers": n}
    if mcfg.encoder_layers:
        kw["encoder_layers"] = n
    return dataclasses.replace(mcfg, **kw)


def build_lowering(arch: str, shape_name: str, mesh, *, variant: str = "full",
                   overrides=None):
    """variant: "full" | "l1" | "l2" (layer-delta probes) | "avg"
    (averaging-only: isolates CoDA's periodic all-reduce)."""
    mcfg = get_config(arch)
    shape = SHAPES[shape_name]
    overrides = overrides or {}
    if overrides.get("mcfg_kw"):
        mcfg = dataclasses.replace(mcfg, **overrides["mcfg_kw"])
    flags.MOE_SHARDING_CONSTRAINTS = bool(overrides.get("moe_constraints"))
    policy = overrides.get("policy", R.policy_for(arch))
    multi_pod = "pod" in mesh.axis_names
    use_window = overrides.get(
        "use_window",
        shape_name == "long_500k" or mcfg.window_mode == "all_but_global")
    if variant == "l1":
        mcfg = _with_layers(mcfg, 1)
    elif variant == "l2":
        mcfg = _with_layers(mcfg, 2)

    meta = dict(arch=arch, shape=shape_name, policy=policy,
                multi_pod=multi_pod, n_chips=mesh.size, use_window=use_window,
                variant=variant)

    if shape.kind == "train":
        K = MESH.n_workers(mesh, policy)
        ccfg = coda.CoDAConfig(n_workers=K, param_dtype=jnp.bfloat16,
                               use_window=use_window, p_pos=0.71,
                               avg_compress=overrides.get("avg_compress", ""))
        key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
        state_shapes = jax.eval_shape(
            lambda k: coda.init_state(k, mcfg, ccfg), key_spec)
        st_sh = R.state_shardings(state_shapes, mesh, policy, multi_pod)
        if variant == "avg":
            fn = lambda st: coda.average(
                st, compress=overrides.get("avg_compress") or None)
            jitted = jax.jit(fn, in_shardings=(st_sh,), out_shardings=st_sh)
            with mesh:
                lowered = jitted.lower(state_shapes)
            meta.update(n_workers=K, step_kind="coda_average")
            return lowered, meta
        batch_shapes = input_specs(mcfg, shape, n_workers=K, window_steps=1)
        bt_sh = R.batch_shardings(batch_shapes, mesh, policy, multi_pod)
        eta_spec = jax.ShapeDtypeStruct((), jnp.float32)
        fn = lambda st, wb, eta: coda.window_step(mcfg, ccfg, st, wb, eta)
        jitted = jax.jit(fn, in_shardings=(st_sh, bt_sh, None),
                         out_shardings=(st_sh, None))
        with mesh:
            lowered = jitted.lower(state_shapes, batch_shapes, eta_spec)
        meta.update(n_workers=K,
                    tokens_per_step=shape.global_batch * shape.seq_len,
                    step_kind="coda_window",
                    state_bytes=_spec_bytes(state_shapes))
        return lowered, meta

    from repro.models import model as M
    params_shapes = jax.eval_shape(
        lambda k: M.init_params(k, mcfg, dtype=jnp.bfloat16),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_sh = R.tree_shardings(params_shapes, mesh, policy, worker_axes=())

    if shape.kind == "prefill":
        batch_shapes = input_specs(mcfg, shape, n_workers=1, window_steps=1)
        batch_shapes = {k: jax.ShapeDtypeStruct(v.shape[2:], v.dtype)
                        for k, v in batch_shapes.items() if k != "labels"}
        bt_sh = R.serve_shardings(batch_shapes, mesh)
        fn = lambda p, b: M.prefill_step(mcfg, p, b, use_window=use_window)
        jitted = jax.jit(fn, in_shardings=(p_sh, bt_sh))
        with mesh:
            lowered = jitted.lower(params_shapes, batch_shapes)
        meta.update(step_kind="prefill",
                    tokens_per_step=shape.global_batch * shape.seq_len,
                    state_bytes=_spec_bytes(params_shapes))
        return lowered, meta

    # decode (layer loop is Python-unrolled — costs are honest as-is)
    B, S = shape.global_batch, shape.seq_len
    cache_shapes = D.cache_specs(mcfg, B, S, use_window=use_window,
                                 dtype=overrides.get("cache_dtype", jnp.bfloat16))
    c_sh = R.serve_shardings(cache_shapes, mesh,
                             cache_shard=overrides.get("cache_shard", "heads"))
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    io_sh = R.serve_shardings({"t": tok, "p": pos}, mesh)
    fn = lambda p, c, t, ps: D.serve_step(mcfg, p, c, t, ps,
                                          use_window=use_window)
    jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, io_sh["t"], io_sh["p"]),
                     out_shardings=(None, None, c_sh))
    with mesh:
        lowered = jitted.lower(params_shapes, cache_shapes, tok, pos)
    meta.update(step_kind="decode", tokens_per_step=B,
                state_bytes=_spec_bytes(params_shapes) + _spec_bytes(cache_shapes))
    return lowered, meta


def _compile_costs(arch, shape_name, mesh, variant, overrides):
    t0 = time.time()
    lowered, meta = build_lowering(arch, shape_name, mesh, variant=variant,
                                   overrides=overrides)
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:
        mem_rec = {}
    coll = H.collective_bytes(compiled.as_text())
    return dict(
        meta=meta,
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        collectives=coll,
        memory=mem_rec,
        seconds=round(time.time() - t0, 1),
    )


# which families have a scanned (rolled) layer stack needing the L-delta
_SCANNED = ("dense", "moe", "vlm", "audio", "hybrid")


def run_pair(arch: str, shape_name: str, *, multi_pod: bool,
             save: bool = True, verbose: bool = True, overrides=None,
             tag_suffix: str = "") -> dict:
    skip = is_skipped(arch, shape_name)
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}{tag_suffix}"
    if skip:
        rec = dict(arch=arch, shape=shape_name, multi_pod=multi_pod,
                   status="skipped", reason=skip)
        if save:
            _save(tag, rec)
        if verbose:
            print(f"[dryrun] {tag}: SKIPPED ({skip.split(';')[0][:60]}...)")
        return rec

    mcfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = MESH.make_production_mesh(multi_pod=multi_pod)
    try:
        full = _compile_costs(arch, shape_name, mesh, "full", overrides)
        rec = dict(status="ok", **full["meta"])
        rec.update(full_raw={k: full[k] for k in
                             ("flops", "hbm_bytes", "collectives", "seconds")},
                   memory=full["memory"])

        L = mcfg.n_layers
        # With DRYRUN_UNROLL the layer scan is already unrolled in the full
        # lowering, so its costs are honest as-is.  The L=1/L=2 delta probes
        # exist for cross-checking that methodology (REPRO_DRYRUN_DELTAS=1)
        # but are NOT added to the totals.
        full_only = multi_pod and bool(os.environ.get("REPRO_MULTIPOD_FULL_ONLY"))
        needs_delta = (bool(os.environ.get("REPRO_DRYRUN_DELTAS"))
                       and mcfg.family in _SCANNED and shape.kind != "decode"
                       and L > 2 and not full_only)
        if needs_delta:
            # cross-check only: honest-total should be ~ F_nonlayer + L*delta
            l1 = _compile_costs(arch, shape_name, mesh, "l1", overrides)
            l2 = _compile_costs(arch, shape_name, mesh, "l2", overrides)
            rec["layer_delta_check"] = dict(
                flops=max(0.0, l2["flops"] - l1["flops"]),
                hbm_bytes=max(0.0, l2["hbm_bytes"] - l1["hbm_bytes"]),
                coll_bytes=max(0, l2["collectives"]["total_bytes"]
                               - l1["collectives"]["total_bytes"]),
                l1_flops=l1["flops"])

        nw = rec.get("n_workers", 1)
        rec["flops"] = full["flops"] + slstm_flop_correction(mcfg, shape, nw)
        rec["hbm_bytes"] = full["hbm_bytes"]
        rec["coll_bytes"] = full["collectives"]["total_bytes"]
        rec["collectives"] = full["collectives"]

        if shape.kind == "train" and not full_only:
            avg = _compile_costs(arch, shape_name, mesh, "avg", overrides)
            rec["avg_coll_bytes"] = avg["collectives"]["total_bytes"]
            rec["avg_collectives"] = avg["collectives"]

        from repro.models import model as M
        rec["n_params"] = M.count_params(mcfg)
        rec["n_params_active"] = M.count_params(mcfg, active_only=True)
    except Exception as e:  # a failure here is a bug in the system
        rec = dict(arch=arch, shape=shape_name, multi_pod=multi_pod,
                   status="FAILED", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if save:
            _save(tag, rec)
        if verbose:
            print(f"[dryrun] {tag}: FAILED {e}")
        return rec

    if save:
        _save(tag, rec)
    if verbose:
        print(f"[dryrun] {tag}: ok flops/step={rec['flops']:.3e} "
              f"hbm={rec['hbm_bytes']:.3e} coll={rec['coll_bytes']:.3e} "
              f"avg_coll={rec.get('avg_coll_bytes', 0):.3e} "
              f"compile={full['seconds']}s", flush=True)
    return rec


def _save(tag: str, rec: dict):
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    with open(os.path.join(ARTIFACT_DIR, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                run_pair(arch, shape, multi_pod=mp)


if __name__ == "__main__":
    main()
