"""Process-wide tracing flags.

``DRYRUN_UNROLL`` — set (only) by launch/dryrun.py before tracing.  XLA's
cost_analysis counts a while-loop body ONCE regardless of trip count, so the
dry-run unrolls the structural scans (layer stack, CoDA window, mLSTM chunk
loop, chunked-attention KV loop) to make HLO_FLOPs/HLO_bytes honest.  Normal
execution keeps rolled scans (fast compiles, small HLO).

The strictly-sequential sLSTM time scan is never unrolled (S ≤ 524288 steps);
launch/dryrun.py adds its analytic per-step FLOPs × (S-1) correction instead.
"""

DRYRUN_UNROLL = False

# §Perf knob: insert explicit with_sharding_constraint on the MoE dispatch
# intermediates (expert axis over "data", ff over "model") instead of letting
# GSPMD propagate through the gather/scatter.  Requires an active mesh whose
# axes include "data"/"model"; set only by the dry-run hillclimb.
MOE_SHARDING_CONSTRAINTS = False


def scan_unroll():
    """Value for lax.scan(..., unroll=...)."""
    return True if DRYRUN_UNROLL else 1


def attn_chunk(skv: int, default: int = 512) -> int:
    """KV chunk for the online-softmax fallback.  Under the dry-run the chunk
    count is capped at 8 so the unrolled loop stays compilable."""
    if DRYRUN_UNROLL:
        return max(default, -(-skv // 8))
    return default


def mlstm_chunk(s: int, default: int = 256) -> int:
    """Under the dry-run, cap the chunk count at 8 (like attention) so the
    unrolled chunk loop stays compilable on one core."""
    if DRYRUN_UNROLL:
        return max(default, -(-s // 8))
    return default
