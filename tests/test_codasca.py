"""CODASCA tests: the non-IID Dirichlet partitioner, control-variate
algebra on the vmap oracle (α = ∞ / homogeneous shards must reduce
CODASCA to CoDA *exactly*), shard_map equivalence on 8 forced host
devices, and the acceptance invariant — one compiled CODASCA window =
exactly ONE cross-worker all-reduce of the documented state +
control-variate payload (2 × model_bytes), checked against the HLO.

Mesh-parallel checks run in subprocesses because
``--xla_force_host_platform_device_count`` must be set before jax
initialises its backend (same pattern as tests/test_coda_sharded.py).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import mlp_config
from repro.core import coda, codasca, schedules
from repro.data import DataConfig, ShardedDataset
from repro.data.synthetic import dirichlet_partition

MCFG = mlp_config(n_features=16, d=32)


def _case(K, I, B=8, seed=0, algorithm="codasca", compress="",
          param_dtype=jnp.float32):
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7, algorithm=algorithm,
                           avg_compress=compress, param_dtype=param_dtype)
    key = jax.random.PRNGKey(seed)
    st0 = coda.init_state(key, MCFG, ccfg)
    ky, kx = jax.random.split(key)
    y = (jax.random.uniform(ky, (I, K, B)) < 0.7).astype(jnp.float32)
    x = jax.random.normal(kx, (I, K, B, 16)) + 0.3 * (y[..., None] * 2 - 1)
    return ccfg, st0, {"features": x, "labels": y}


def _max_err(a, b):
    return max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x - y))), a, b)))


_STATE_KEYS = ("params", "duals", "ref_params", "ref_duals")


def _state_only(state):
    return {k: state[k] for k in ("params", "duals")}


# --------------------------------------------------------------------------
# non-IID partitioner
# --------------------------------------------------------------------------
def test_dirichlet_partition_exact_and_keeps_every_positive():
    """The shards tile [0, n) exactly — every sample, in particular every
    positive, lands in exactly one shard; no worker starves."""
    rng = np.random.RandomState(0)
    labels = (rng.uniform(size=977) < 0.71).astype(np.float32)
    for alpha in (0.05, 0.5, 5.0):
        shards = dirichlet_partition(np.random.RandomState(1), labels, 8, alpha)
        allidx = np.sort(np.concatenate(shards))
        np.testing.assert_array_equal(allidx, np.arange(len(labels)))
        assert all(len(s) > 0 for s in shards)
        n_pos = sum(int(labels[s].sum()) for s in shards)
        assert n_pos == int(labels.sum())  # every positive retained


def test_dirichlet_skew_tracks_alpha():
    """Small α ⇒ large spread of per-shard positive ratios; large α ⇒ the
    IID limit; α=None/∞ keeps the paper's even split."""
    key = jax.random.PRNGKey(0)
    dcfg = DataConfig(kind="features", n_features=8)

    def spread(alpha):
        ds = ShardedDataset(key, dcfg, 2048, 8, target_p=0.71,
                            dirichlet_alpha=alpha)
        return float(np.std(ds.shard_p_pos)), ds

    s_skew, ds_skew = spread(0.1)
    s_mid, _ = spread(1.0)
    s_iid, _ = spread(1000.0)
    assert s_skew > s_mid > s_iid, (s_skew, s_mid, s_iid)
    assert s_skew > 0.2 and s_iid < 0.05
    # skewed shards are unequal but complete
    assert sum(ds_skew.shard_sizes) == ds_skew.n
    # the ∞/None path is the historical even split
    ds_inf = ShardedDataset(key, dcfg, 2048, 8, target_p=0.71,
                            dirichlet_alpha=float("inf"))
    ds_none = ShardedDataset(key, dcfg, 2048, 8, target_p=0.71)
    assert ds_inf.shard_sizes == ds_none.shard_sizes
    for a, b in zip(ds_inf.shards, ds_none.shards):
        np.testing.assert_array_equal(a, b)


def test_dirichlet_sampling_stays_in_shard():
    key = jax.random.PRNGKey(3)
    dcfg = DataConfig(kind="features", n_features=8)
    ds = ShardedDataset(key, dcfg, 1024, 4, target_p=0.71, dirichlet_alpha=0.2)
    wb = ds.sample_window(key, 3, 8)
    assert wb["labels"].shape == (3, 4, 8)
    ab = ds.sample_alpha_batch(key, 16)
    assert ab["labels"].shape[0] == 4


# --------------------------------------------------------------------------
# vmap-oracle algebra: the homogeneous limit IS CoDA
# --------------------------------------------------------------------------
def test_codasca_first_window_is_coda_bitwise():
    """Zero-initialised variates make the correction an exact fp zero, so
    window 1 must equal CoDA bit for bit."""
    K, I = 4, 3
    ccfg, st0, wb = _case(K, I)
    c0 = coda.CoDAConfig(n_workers=K, p_pos=0.7)
    s1, l1 = codasca.window_step(MCFG, ccfg, st0, wb, 0.1)
    s2, l2 = coda.window_step(MCFG, c0, {k: st0[k] for k in _STATE_KEYS},
                              wb, 0.1)
    assert _max_err(_state_only(s1), _state_only(s2)) == 0.0
    assert float(jnp.max(jnp.abs(l1 - l2))) == 0.0


def test_codasca_homogeneous_equals_coda_step_for_step():
    """Identical per-worker batches (the α = ∞ limit taken to its extreme):
    every worker computes the same gradients, so c_k == c forever and the
    correction stays an exact zero — CODASCA must track CoDA exactly over
    many windows, not just the first."""
    K, I = 4, 2
    ccfg, st_s, wb = _case(K, I)
    c0 = coda.CoDAConfig(n_workers=K, p_pos=0.7)
    wb_h = {k: jnp.broadcast_to(v[:, :1], v.shape).copy()
            for k, v in wb.items()}
    st_c = {k: st_s[k] for k in _STATE_KEYS}
    for _ in range(4):
        st_s, _ = codasca.window_step(MCFG, ccfg, st_s, wb_h, 0.1)
        st_c, _ = coda.window_step(MCFG, c0, st_c, wb_h, 0.1)
    assert _max_err(_state_only(st_s), _state_only(st_c)) == 0.0


def test_codasca_k1_equals_coda_over_windows():
    """K = 1 (PPD-SG degenerate): the worker mean of one worker is itself,
    so c_1 == c after every refresh and CODASCA ≡ CoDA exactly — even with
    fresh (different) batches per window."""
    ccfg, st_s, _ = _case(1, 2)
    c0 = coda.CoDAConfig(n_workers=1, p_pos=0.7)
    st_c = {k: st_s[k] for k in _STATE_KEYS}
    for seed in range(3):
        _, _, wb = _case(1, 2, seed=seed)
        st_s, _ = codasca.window_step(MCFG, ccfg, st_s, wb, 0.1)
        st_c, _ = coda.window_step(MCFG, c0, st_c, wb, 0.1)
    assert _max_err(_state_only(st_s), _state_only(st_c)) == 0.0


def test_codasca_variate_invariant_and_payload():
    """After a heterogeneous window: cg == mean_k cv (the SCAFFOLD server
    invariant, maintained here by the shared all-reduce), corrections are
    mean-zero across workers, and the accounted payload doubles."""
    ccfg, st0, wb = _case(8, 4)
    s1, _ = codasca.window_step(MCFG, ccfg, st0, wb, 0.1)
    err = jax.tree_util.tree_map(
        lambda cg, cv: float(jnp.max(jnp.abs(cg - jnp.mean(cv, axis=0)))),
        s1["cg_params"], s1["cv_params"])
    assert max(jax.tree_util.tree_leaves(err)) < 1e-6
    assert float(jnp.max(jnp.abs(s1["cg_duals"]["a"]
                                 - jnp.mean(s1["cv_duals"]["a"])))) < 1e-6
    # the variates are not trivially zero on heterogeneous batches
    assert max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda cv: float(jnp.max(jnp.abs(cv))), s1["cv_params"]))) > 0
    assert coda.window_payload_bytes(s1) == 2 * coda.model_bytes(s1)
    assert coda.window_payload_bytes(_state_only(s1)) == \
        coda.model_bytes(s1)


def test_codasca_int8_shares_quantizer_between_c_and_ck():
    """Under int8 averaging, cg must equal mean_k cv (both in wire format):
    the stored per-worker variates are the dequantized payload, so the
    SCAFFOLD invariant — and hence the K=1 equivalence with int8 CoDA —
    survives quantization."""
    ccfg, st0, wb = _case(8, 3, compress="int8")
    s1, _ = codasca.window_step(MCFG, ccfg, st0, wb, 0.1)
    err = jax.tree_util.tree_map(
        lambda cg, cv: float(jnp.max(jnp.abs(cg - jnp.mean(cv, axis=0)))),
        s1["cg_params"], s1["cv_params"])
    assert max(jax.tree_util.tree_leaves(err)) < 1e-6
    # K=1: int8 CODASCA ≡ int8 CoDA over multiple windows (corrections
    # cancel exactly because c and c_1 share the quantizer)
    ccfg1, st_s, _ = _case(1, 2, compress="int8")
    c0 = coda.CoDAConfig(n_workers=1, p_pos=0.7, avg_compress="int8")
    st_c = {k: st_s[k] for k in _STATE_KEYS}
    for seed in range(3):
        _, _, wb1 = _case(1, 2, seed=seed, compress="int8")
        st_s, _ = codasca.window_step(MCFG, ccfg1, st_s, wb1, 0.1)
        st_c, _ = coda.window_step(MCFG, c0, st_c, wb1, 0.1)
    assert _max_err(_state_only(st_s), _state_only(st_c)) == 0.0


def test_codasca_bf16_homogeneous_equals_coda():
    """The α = ∞ equivalence must survive ``param_dtype=bfloat16``:
    identical per-worker batches keep every variate pair bitwise equal, so
    the correction stays an exact zero and bf16 CODASCA tracks bf16 CoDA
    exactly over multiple windows — including through the fp32 variate
    accumulator and its cast back to the bf16 wire dtype."""
    K, I = 4, 4
    ccfg, st_s, wb = _case(K, I, param_dtype=jnp.bfloat16)
    c0 = coda.CoDAConfig(n_workers=K, p_pos=0.7, param_dtype=jnp.bfloat16)
    wb_h = {k: jnp.broadcast_to(v[:, :1], v.shape).copy()
            for k, v in wb.items()}
    st_c = {k: st_s[k] for k in _STATE_KEYS}
    for _ in range(3):
        st_s, _ = codasca.window_step(MCFG, ccfg, st_s, wb_h, 0.1)
        st_c, _ = coda.window_step(MCFG, c0, st_c, wb_h, 0.1)
    assert _max_err(_state_only(st_s), _state_only(st_c)) == 0.0
    # the wire format stays the per-leaf param dtype (c and c_k must share
    # the params' bucket layout; note the model keeps score_head.b fp32)
    assert all(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda cv, p: cv.dtype == p.dtype,
        st_s["cv_params"], st_s["params"])))
    assert any(l.dtype == jnp.bfloat16 for l in
               jax.tree_util.tree_leaves(st_s["cv_params"]))


def test_codasca_bf16_variate_refresh_accumulates_fp32(monkeypatch):
    """THE bf16 accumulator regression: the window-mean variate refresh must
    be the fp32-accumulated mean of the raw gradients, cast to the wire
    dtype once at the refresh.  Gradients are stubbed to the adversarial
    pattern [1, ε, ε, ...] with ε = 2⁻⁹ — below the bf16 ulp of the
    running sum, so a bf16 accumulator (the old ``zeros_like(params)``
    layout) silently drops every ε and lands on mean 1/I instead of
    (1 + (I−1)ε)/I.  The fp32 path must match the exact binary arithmetic
    bit for bit."""
    K, I, B, eps = 4, 32, 8, 2.0 ** -9
    ccfg, st0, wb = _case(K, I, B=B, param_dtype=jnp.bfloat16, seed=3)
    # encode the per-step gradient value in the labels: g_0 = 1, g_t = ε
    g_t = np.full((I,), eps, np.float32)
    g_t[0] = 1.0
    wb["labels"] = jnp.broadcast_to(
        jnp.asarray(g_t)[:, None, None], (I, K, B)).copy()

    def stub_grad_step(mcfg, c, state, batch):
        val = batch["labels"][0, 0]        # this step's scripted gradient
        gp = jax.tree_util.tree_map(
            lambda p: jnp.full(p.shape, val).astype(p.dtype),
            state["params"])
        K_ = state["duals"]["a"].shape[0]
        gd = {f: jnp.full((K_,), val) for f in state["duals"]}
        return jnp.zeros((K_,)), (gp, gd)

    monkeypatch.setattr(coda, "grad_step", stub_grad_step)
    s1, _ = codasca.window_step(MCFG, ccfg, st0, wb, 0.1)

    want = np.float32(1.0 + (I - 1) * eps) / np.float32(I)  # exact in fp32
    for leaf in jax.tree_util.tree_leaves(s1["cv_params"]):
        got = np.unique(np.asarray(leaf.astype(jnp.float32)))
        assert got.shape == (1,), got
        assert got[0] == np.float32(jnp.bfloat16(want)) if \
            leaf.dtype == jnp.bfloat16 else got[0] == want, \
            (leaf.dtype, got[0], want)
    # the broken bf16 accumulator would have produced exactly 1/I
    assert float(jnp.bfloat16(want)) != 1.0 / I
    assert float(s1["cv_duals"]["a"][0]) == want           # fp32 lane


def test_config_rejects_unknown_algorithm():
    """A typo'd algorithm must fail loudly at config time — the sharded
    executor dispatches on equality and would otherwise silently train
    plain CoDA."""
    import pytest
    with pytest.raises(ValueError):
        coda.CoDAConfig(n_workers=2, algorithm="CODASCA")
    with pytest.raises(ValueError):
        coda.CoDAConfig(n_workers=2, avg_compress="int4")


def test_codasca_fit_accounting():
    """fit() with the codasca vmap executor: runs multi-stage with donation,
    and comm_bytes charges the doubled window payload."""
    key = jax.random.PRNGKey(0)
    K = 4
    ds = ShardedDataset(key, DataConfig(kind="features", n_features=16),
                        1024, K, target_p=0.7, dirichlet_alpha=0.3)
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=ds.p_pos, algorithm="codasca")
    sched = schedules.ScheduleConfig(n_workers=K, eta0=0.5, T0=8, I0=4)
    res = coda.fit(key, MCFG, ccfg, sched, 2,
                   sample_window=lambda k, i: ds.sample_window(k, i, 16),
                   sample_alpha_batch=lambda k, m: ds.sample_alpha_batch(k, m),
                   executor="vmap")
    sl = schedules.stages(sched, 2)
    assert res.comm_rounds == coda.comm_rounds(sl)
    assert all(np.isfinite(h[2]) for h in res.history)
    n_windows = sum(-(-s.T // s.I) for s in sl)
    assert coda.comm_bytes(sl, res.state) == \
        n_windows * 2 * coda.model_bytes(res.state) + 2 * 4


# --------------------------------------------------------------------------
# shard_map equivalence + the compiled-payload acceptance invariant
# --------------------------------------------------------------------------
_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import mlp_config
    from repro.core import coda, codasca
    from repro.analysis import audit as A
    from repro.analysis import hlo as H

    mcfg = mlp_config(n_features=16, d=32)

    def make_case(K, I, B=8, compress="", seed=0):
        ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7, avg_compress=compress,
                               algorithm="codasca")
        key = jax.random.PRNGKey(seed)
        st0 = coda.init_state(key, mcfg, ccfg)
        ky, kx = jax.random.split(key)
        y = (jax.random.uniform(ky, (I, K, B)) < 0.7).astype(jnp.float32)
        x = jax.random.normal(kx, (I, K, B, 16)) + 0.3 * (y[..., None] * 2 - 1)
        wb = {"features": x, "labels": y}
        ab = {"features": x[0], "labels": y[0]}
        return ccfg, st0, wb, ab

    def assert_trees_close(got, want, tol, label):
        for (p, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(got)[0],
                                  jax.tree_util.tree_flatten_with_path(want)[0]):
            err = float(jnp.max(jnp.abs(a - b)))
            assert err < tol, (label, jax.tree_util.keystr(p), err)
""")


def _run(script: str, timeout=900):
    r = subprocess.run([sys.executable, "-c", _PRELUDE + textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "ALL OK" in r.stdout, r.stdout[-2000:]


def test_codasca_shard_map_matches_vmap_oracle():
    """Multi-window CODASCA through shard_map (control variates riding the
    window all-reduce) must match the oracle to fp32 tolerance — fp32 and
    int8 buckets, plus the K=1 degenerate case."""
    _run("""
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    for label, K, I, compress in [("fp32 K=8", 8, 4, ""),
                                  ("int8 K=8", 8, 2, "int8"),
                                  ("fp32 K=1", 1, 3, "")]:
        ccfg, st0, wb, ab = make_case(K, I, compress=compress)
        exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                                 donate=False)
        st = exe.place(st0)
        rt = st0
        for _ in range(2):  # two windows: variates are live in window 2
            st, losses = exe.window_step(st, wb, 0.1)
            rt, rl = codasca.window_step(mcfg, ccfg, rt, wb, 0.1)
        st2 = exe.stage_end(st, ab)
        rt2 = coda.stage_end(mcfg, ccfg, rt, ab, resync=False)
        assert losses.shape == (I, K), (label, losses.shape)
        assert_trees_close(st, rt, 1e-5, label + "/window")
        assert_trees_close(st2, rt2, 1e-5, label + "/stage")
        np.testing.assert_allclose(np.asarray(jnp.mean(losses, axis=1)),
                                   np.asarray(rl), atol=1e-5)
        print("OK", label)
    print("ALL OK")
    """)


def test_codasca_window_is_one_allreduce_of_double_payload():
    """THE acceptance invariant: the compiled CODASCA window contains
    exactly ONE cross-worker all-reduce whose operand bytes equal the
    documented state + control-variate payload (2 × model_bytes); with
    communicate=False the window is collective-silent; the stage boundary
    still ships one f32 scalar."""
    _run("""
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    K, B = 8, 8
    ccfg, st0, _, ab = make_case(K, 1, B=B)
    exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh, donate=False)

    def window_txt(I, communicate=True):
        wb = {"features": jax.ShapeDtypeStruct((I, K, B, 16), jnp.float32),
              "labels": jax.ShapeDtypeStruct((I, K, B), jnp.float32)}
        sts = jax.eval_shape(lambda s: s, st0)
        return exe.window_fn(sts, wb, communicate=communicate).lower(
            sts, wb, jax.ShapeDtypeStruct((), jnp.float32)).compile().as_text()

    payload = coda.window_payload_bytes(st0)
    assert payload == 2 * coda.model_bytes(st0)
    for I in (1, 4, 8):
        ops = A.assert_window_payload(window_txt(I), payload)
        assert "0,1,2,3,4,5,6,7" in ops[0]["replica_groups"], ops[0]
    assert H.collective_ops(window_txt(4, communicate=False)) == []

    sts = jax.eval_shape(lambda s: s, st0)
    stage_ops = H.collective_ops(
        exe.stage_fn(sts, ab).lower(sts, ab).compile().as_text())
    assert len(stage_ops) == 1 and stage_ops[0]["bytes"] == 4

    # and the CoDA window still ships exactly model_bytes — the helper
    # flags any drift either way
    ccfg0, st0c, _, _ = make_case(K, 1, B=B)
    import dataclasses
    ccfg0 = dataclasses.replace(ccfg0, algorithm="coda")
    st0c = {k: v for k, v in st0c.items() if not k.startswith(("cv_", "cg_"))}
    exe0 = coda.make_executor(mcfg, ccfg0, "shard_map", mesh=mesh,
                              donate=False)
    wb = {"features": jax.ShapeDtypeStruct((4, K, B, 16), jnp.float32),
          "labels": jax.ShapeDtypeStruct((4, K, B), jnp.float32)}
    sts = jax.eval_shape(lambda s: s, st0c)
    txt = exe0.window_fn(sts, wb).lower(
        sts, wb, jax.ShapeDtypeStruct((), jnp.float32)).compile().as_text()
    A.assert_window_payload(txt, coda.model_bytes(st0c))
    try:
        A.assert_window_payload(txt, 2 * coda.model_bytes(st0c))
        raise SystemExit("assert_window_payload missed a byte mismatch")
    except AssertionError:
        pass
    print("ALL OK")
    """)
