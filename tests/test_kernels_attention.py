"""flash_attention Pallas kernel vs the pure-jnp oracle (interpret mode),
swept over shapes / dtypes / GQA groups / masking modes, plus the chunked
online-softmax fallback vs the materialized reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels import ref


def _qkv(key, B, S, H, KV, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), dtype)
    k = jax.random.normal(kk, (B, S, KV, hd), dtype)
    v = jax.random.normal(kv, (B, S, KV, hd), dtype)
    return q, k, v


SHAPES = [
    # B, S, H, KV, hd, bq, bk
    (1, 128, 4, 4, 32, 64, 64),
    (2, 256, 4, 2, 16, 64, 128),   # GQA 2:1
    (1, 128, 8, 1, 64, 32, 32),    # MQA
    (2, 64, 2, 2, 128, 64, 64),    # single q block
    (1, 192, 3, 1, 8, 64, 64),     # odd head count, 3 kv blocks
]


@pytest.mark.parametrize("B,S,H,KV,hd,bq,bk", SHAPES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_vs_ref(B, S, H, KV, hd, bq, bk, causal):
    q, k, v = _qkv(jax.random.PRNGKey(B * S + H), B, S, H, KV, hd, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    exp = ref.attention_full(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [16, 64, 100])
def test_flash_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(7), 1, 256, 4, 2, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    exp = ref.attention_full(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    q, k, v = _qkv(jax.random.PRNGKey(3), 2, 128, 4, 4, 32, dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    exp = ref.attention_full(q, k, v, causal=True)
    assert out.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 48])
def test_chunked_vs_full(causal, window):
    q, k, v = _qkv(jax.random.PRNGKey(5), 2, 256, 4, 2, 32, jnp.float32)
    out = ref.attention_chunked(q, k, v, causal=causal, window=window, chunk=64)
    exp = ref.attention_full(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


def test_chunked_traced_window():
    """Traced window scalars (the scanned hybrid-stack path) must match."""
    q, k, v = _qkv(jax.random.PRNGKey(9), 1, 128, 4, 4, 16, jnp.float32)

    def f(w):
        return ref.attention_full(q, k, v, causal=True, window=w)

    out = jax.jit(f)(jnp.int32(32))
    exp = ref.attention_full(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-6)
    # window = -1 means full
    out_full = jax.jit(f)(jnp.int32(-1))
    exp_full = ref.attention_full(q, k, v, causal=True, window=None)
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(exp_full),
                               atol=2e-6)


def test_cross_attention_no_causal():
    kq, kk = jax.random.split(jax.random.PRNGKey(11))
    q = jax.random.normal(kq, (2, 32, 4, 16))
    k = jax.random.normal(kk, (2, 96, 2, 16))
    v = jax.random.normal(kk, (2, 96, 2, 16))
    out = ref.attention_chunked(q, k, v, causal=False, chunk=32)
    exp = ref.attention_full(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)
