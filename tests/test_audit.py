"""Red-team tests for the compiled-program auditor (analysis/audit.py).

Every rule must FAIL on a deliberately-violating program and pass on the
real stack — a rule that cannot reject its counterexample is decoration,
not a gate.  The violating programs are real jitted artifacts where jax
can produce them in-process (R2's unaliasable donation, R3's f64 /
callback / narrow-accumulation jaxprs, R5's broken geometry) and
hand-written HLO where the violation is about wire schedule shape (R1's
smuggled collective, degenerate ring).  The real-stack pass runs the full
capture + rule engine over the training executors and the serving engine
in an 8-device subprocess, including a shard_map local step with a
smuggled pmean that R1 must reject.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import audit as A

# hand-written window HLO: ONE f32 all-reduce of 400 bytes
_WINDOW_OK = "%ar = f32[100]{0} all-reduce(%p0), replica_groups={{0,1}}"
# ...and the violations
_WINDOW_SMUGGLED = _WINDOW_OK + "\n%ar2 = f32[25]{0} all-reduce(%p1)"
_WINDOW_WRONG_KIND = "%ag = f32[100]{0} all-gather(%p0)"


def _prog(name, hlo, expect):
    return A.CompiledProgram(name=name, hlo_text=hlo, expect=expect)


# --------------------------------------------------------------------------
# R1 — collective placement
# --------------------------------------------------------------------------
def test_r1_collective_free_rejects_smuggled_collective():
    prog = _prog("local_step", _WINDOW_OK, {"collectives": {"kind": "none"}})
    findings = A.rule_collective_placement(prog)
    assert findings and findings[0].rule == "R1"
    clean = _prog("local_step", "%d = f32[8,8]{1,0} dot(%a, %b)",
                  {"collectives": {"kind": "none"}})
    assert A.rule_collective_placement(clean) == []


def test_r1_window_rejects_second_all_reduce_and_wrong_kind():
    ok = _prog("window", _WINDOW_OK,
               {"collectives": {"kind": "window", "expected_bytes": 400}})
    assert A.rule_collective_placement(ok) == []
    for bad_hlo in (_WINDOW_SMUGGLED, _WINDOW_WRONG_KIND, ""):
        bad = _prog("window", bad_hlo,
                    {"collectives": {"kind": "window",
                                     "expected_bytes": 400}})
        assert A.rule_collective_placement(bad), bad_hlo
    short = _prog("window", _WINDOW_OK,
                  {"collectives": {"kind": "window", "expected_bytes": 800}})
    assert "mismatch" in A.rule_collective_placement(short)[0].message


def test_r1_ring_rejects_blocking_all_reduce_and_wrong_hops():
    hops = "\n".join(
        f"%cp{i} = f32[50]{{0}} collective-permute(%x{i})" for i in range(4))
    bad = _prog("pair", hops + "\n" + _WINDOW_OK,
                {"collectives": {"kind": "ring", "n_hops": 4}})
    msgs = [f.message for f in A.rule_collective_placement(bad)]
    assert any("blocking" in m for m in msgs)
    wrong_count = _prog("pair", hops,
                        {"collectives": {"kind": "ring", "n_hops": 6}})
    assert A.rule_collective_placement(wrong_count)


def test_r1_gather_pair_rejects_non_s8_payload():
    ok_hlo = ("%ag1 = s8[800]{0} all-gather(%p)\n"
              "%ag2 = f32[96]{0} all-gather(%s)")
    ok = _prog("int8", ok_hlo, {"collectives": {
        "kind": "gather_pair", "payload_bytes": 148, "n_workers": 8}})
    assert A.rule_collective_placement(ok) == []
    f32_leak = _prog("int8", "%ag = f32[296]{0} all-gather(%p)",
                     {"collectives": {"kind": "gather_pair",
                                      "payload_bytes": 148, "n_workers": 8}})
    assert A.rule_collective_placement(f32_leak)   # bytes match, dtype wrong
    reduce_not_gather = _prog(
        "int8", _WINDOW_OK, {"collectives": {
            "kind": "gather_pair", "payload_bytes": 50, "n_workers": 8}})
    assert A.rule_collective_placement(reduce_not_gather)


def test_window_payload_split_validation_still_raises_valueerror():
    """Parameter-misuse semantics survived the rule-engine refactor."""
    with pytest.raises(ValueError, match="go together"):
        A.assert_window_payload("", 100, baseline_bytes=90)
    _, problems = A.window_payload_problems(
        _WINDOW_OK, 400, baseline_bytes=320, delta_bytes=80)
    assert problems == []


# --------------------------------------------------------------------------
# R2 — donation audit (real compiled programs)
# --------------------------------------------------------------------------
def test_r2_rejects_dropped_donation():
    """Donating a buffer no output can reuse (shape mismatch) must be a
    finding; a same-shape update must alias and pass."""
    x = jnp.arange(4, dtype=jnp.float32)

    grow = jax.jit(lambda v: jnp.concatenate([v, v]), donate_argnums=0)
    bad = A.CompiledProgram.capture("grow", grow, x, donated_leaves=1)
    findings = A.rule_donation(bad)
    assert findings and "donated" in findings[0].message

    inc = jax.jit(lambda v: v + 1, donate_argnums=0)
    good = A.CompiledProgram.capture("inc", inc, x, donated_leaves=1)
    assert A.rule_donation(good) == []


def test_r2_deleted_unused_input_is_not_a_dropped_donation():
    """XLA deleting a donated-but-unused input leaves nothing to alias —
    that is dead-code elimination, not a lost donation."""
    f = jax.jit(lambda v, unused: v * 2, donate_argnums=(0, 1))
    x = jnp.arange(4, dtype=jnp.float32)
    prog = A.CompiledProgram.capture("dce", f, x, x + 1, donated_leaves=2)
    assert A.rule_donation(prog) == []


# --------------------------------------------------------------------------
# R3 — host-sync / dtype lint (real jaxprs)
# --------------------------------------------------------------------------
def _jaxpr_of(fn, *args):
    return jax.make_jaxpr(fn)(*args)


def test_r3_rejects_f64_literal_in_hot_path():
    with jax.experimental.enable_x64():
        jaxpr = _jaxpr_of(lambda v: v * jnp.float64(2.5),
                          jnp.arange(4, dtype=jnp.float64))
    problems = A.jaxpr_problems(jaxpr)
    assert any("f64" in p for p in problems)
    assert A.jaxpr_problems(jaxpr, allow_f64=True) == []


def test_r3_rejects_host_callback():
    def step(v):
        jax.debug.print("v={v}", v=v[0])
        return v + 1
    problems = A.jaxpr_problems(_jaxpr_of(step, jnp.zeros(4)))
    assert any("callback" in p for p in problems)


def test_r3_recurses_into_scan_bodies():
    def windowed(v):
        def body(c, _):
            jax.debug.print("c={c}", c=c[0])
            return c + 1, None
        out, _ = jax.lax.scan(body, v, None, length=3)
        return out
    problems = A.jaxpr_problems(_jaxpr_of(windowed, jnp.zeros(4)))
    assert any("callback" in p for p in problems)


def test_r3_rejects_sub_fp32_accumulation():
    x = jnp.zeros((8, 8), jnp.bfloat16)
    narrow_dot = _jaxpr_of(
        lambda a: jax.lax.dot_general(a, a, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.bfloat16), x)
    assert any("accumulate" in p for p in A.jaxpr_problems(narrow_dot))

    # jnp.sum upcasts even under dtype=bfloat16, so a narrow reduction can
    # only enter a jaxpr through the raw primitive — bind it directly
    narrow_sum = _jaxpr_of(
        lambda a: jax.lax.reduce_sum_p.bind(a, axes=(0, 1)), x)
    assert any("accumulate" in p for p in A.jaxpr_problems(narrow_sum))

    # jnp.sum's default upcast and an fp32-accumulating dot are both clean
    wide = _jaxpr_of(
        lambda a: jnp.sum(a) + jax.lax.dot_general(
            a, a, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).sum(), x)
    assert A.jaxpr_problems(wide) == []


# --------------------------------------------------------------------------
# R4 — recompile budget
# --------------------------------------------------------------------------
def test_r4_rejects_budget_overrun():
    over = A.CompiledProgram(name="serve", compile_count=3,
                             expect={"compiles": {"exact": 2}})
    assert A.rule_recompile_budget(over)
    under = A.CompiledProgram(name="serve", compile_count=1,
                              expect={"compiles": {"exact": 2}})
    assert A.rule_recompile_budget(under)   # exact means exact: 1 != 2
    at_max = A.CompiledProgram(name="fit", compile_count=2,
                               expect={"compiles": {"max": 2}})
    assert A.rule_recompile_budget(at_max) == []
    past_max = A.CompiledProgram(name="fit", compile_count=3,
                                 expect={"compiles": {"max": 2}})
    assert A.rule_recompile_budget(past_max)


# --------------------------------------------------------------------------
# R5 — Pallas static checks
# --------------------------------------------------------------------------
def test_r5_rejects_broken_geometry_and_off_tpu_interpret():
    bad_div = A.PallasLaunch(kernel="k", grid=(3,),
                             blocks={"t": (100, 32)})      # 100 % 32 != 0
    assert A.rule_pallas_static(bad_div)
    bad_grid = A.PallasLaunch(kernel="k", grid=(0, 4),
                              blocks={"t": (64, 32)})
    assert A.rule_pallas_static(bad_grid)
    bad_align = A.PallasLaunch(kernel="k", grid=(1,), blocks={},
                               alignments={"bn%128": (96, 128)})
    assert A.rule_pallas_static(bad_align)
    smuggled_interpret = A.PallasLaunch(kernel="k", grid=(1,),
                                        blocks={"t": (32, 32)},
                                        interpret=True, impl="auto")
    msgs = [f.message for f in A.rule_pallas_static(smuggled_interpret)]
    assert any("interpret" in m for m in msgs)
    explicit = A.PallasLaunch(kernel="k", grid=(1,), blocks={"t": (32, 32)},
                              interpret=True, impl="pallas")
    assert A.rule_pallas_static(explicit) == []


def test_r5_real_kernel_geometry_passes_including_ragged_tails():
    for impl in ("auto", "ref", "pallas"):
        for launch in A.capture_kernel_launches(impl=impl):
            assert A.launch_problems(launch) == [], launch
    # ragged problem sizes that historically tripped tile math
    ragged = A.capture_kernel_launches(
        impl="ref", shapes={"moe": (7, 5, 3, 9), "auc": (12,),
                            "prox": (5,), "flash": (1, 8, 4, 2, 8, 64)})
    for launch in ragged:
        assert A.launch_problems(launch) == [], launch
    assert A.dispatch_problems() == []


# --------------------------------------------------------------------------
# report plumbing
# --------------------------------------------------------------------------
def test_report_aggregates_and_serializes():
    bad = _prog("w", _WINDOW_SMUGGLED,
                {"collectives": {"kind": "window", "expected_bytes": 400}})
    report = A.run_rules([bad], A.capture_kernel_launches(impl="ref"),
                         check_dispatch=False)
    assert not report.ok
    with pytest.raises(AssertionError, match="audit failed"):
        report.raise_if_failed()
    d = report.to_dict()
    assert d["n_findings"] >= 1 and d["rules"]["R1"]["findings"]
    ok = A.run_rules([_prog("w", _WINDOW_OK, {"collectives": {
        "kind": "window", "expected_bytes": 400}})])
    assert ok.ok and ok.to_dict()["ok"]
    ok.raise_if_failed()                     # no-op on a clean report


# --------------------------------------------------------------------------
# the real stack, on a real 8-device mesh (subprocess: XLA_FLAGS must be
# set before jax initialises its backend)
# --------------------------------------------------------------------------
def _run(script: str, timeout=900):
    prelude = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.analysis import audit as A
        from repro.configs.base import mlp_config
        from repro.core import coda
        mcfg = mlp_config(n_features=16, d=32)
    """)
    r = subprocess.run([sys.executable, "-c", prelude + textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "ALL OK" in r.stdout, r.stdout[-2000:]


def test_real_stack_passes_and_smuggled_pmean_fails():
    """The full capture + rule engine over both executors passes on the
    real stack, and a shard_map local-step body with a smuggled pmean is
    rejected by R1 — the audit can tell the real program from a subtly
    broken one on the same mesh."""
    _run("""
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((8, 1), ("data", "model"))
    for algorithm in ("coda", "codasca"):
        ccfg = coda.CoDAConfig(n_workers=8, algorithm=algorithm)
        programs = A.capture_training_programs(
            mcfg, ccfg, executor="shard_map", mesh=mesh,
            window_lens=(1, 2), tag=f"sharded/{algorithm}")
        programs += A.capture_training_programs(
            mcfg, ccfg, executor="vmap", window_lens=(1, 2),
            tag=f"vmap/{algorithm}")
        A.run_rules(programs, check_dispatch=False).raise_if_failed()

    # red-team: a "local step" that sneaks a pmean over the worker axis
    def leaky_local_step(v):
        return v - 0.1 * jax.lax.pmean(v * v, "data")

    leaky = jax.jit(shard_map(
        leaky_local_step, mesh=mesh, in_specs=P("data"),
        out_specs=P("data")))
    prog = A.CompiledProgram.capture(
        "leaky_local_step", leaky, jnp.zeros((8, 4)),
        expect={"collectives": {"kind": "none"}})
    report = A.run_rules([prog], check_dispatch=False)
    assert not report.ok, "R1 must reject the smuggled pmean"
    assert any(f.rule == "R1" for f in report.findings)
    print("ALL OK")
    """)


def test_real_serving_stack_passes_audit():
    """The serving engine's two chunk programs pass every rule, and the R4
    compile budget of exactly two executables holds over a live mixed
    prefill/decode workload."""
    _run("""
    programs = A.capture_serving_programs(slots=2, max_len=32,
                                          prefill_chunk=4)
    report = A.run_rules(programs, check_dispatch=False)
    report.raise_if_failed()
    cache = [p for p in programs if p.name.endswith("chunk_step_cache")]
    assert cache and cache[0].compile_count == 2, cache
    print("ALL OK")
    """)
