"""Overlapped window averaging (the PR 3 tentpole): chunked ppermute-ring
reduce-scatter/all-gather hidden under next-window compute.

Covers the acceptance invariants: the fused window pair's compiled HLO is
C collective-permute chains per ring interleaved with the second window's
dot compute — NO blocking all-reduce — and the overlapped path's final
state equals the blocking path's to fp32 tolerance for both CoDA and
CODASCA (the ring mean is the same mean, just scheduled differently).
Also the fit() pair-feeding driver (odd trailing window, exposed vs
overlapped byte accounting) and the config-level guards.

Mesh-parallel checks run in subprocesses because
``--xla_force_host_platform_device_count`` must be set before jax
initialises its backend (same pattern as tests/test_coda_sharded.py).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.core import bucketing, coda

_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.analysis import audit as A
    from repro.analysis import hlo as H
    from repro.configs.base import mlp_config
    from repro.core import bucketing, coda, codasca

    mcfg = mlp_config(n_features=16, d=32)

    def make_case(K, I, B=8, seed=0, algorithm="coda", overlap=0):
        ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7, algorithm=algorithm,
                               overlap_chunks=overlap)
        key = jax.random.PRNGKey(seed)
        st0 = coda.init_state(key, mcfg, ccfg)
        ky, kx = jax.random.split(key)
        y = (jax.random.uniform(ky, (I, K, B)) < 0.7).astype(jnp.float32)
        x = jax.random.normal(kx, (I, K, B, 16)) + 0.3 * (y[..., None] * 2 - 1)
        return ccfg, st0, {"features": x, "labels": y}

    def as_pair(wb, I):
        return jax.tree_util.tree_map(
            lambda l: l.reshape((2, I) + l.shape[1:]), wb)

    def max_err(a, b):
        return max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
            lambda x, y: float(jnp.max(jnp.abs(x - y))), a, b)))

    def pair_meta(st0, K, chunks, algorithm):
        # (hops, chains) for the two rings of a fused window pair
        mats, _, _ = bucketing._state_mats(st0)
        if algorithm == "codasca":
            mats = mats * 2      # the variates ride the same dtype buckets
        ring = bucketing.RingSpec("data", K, chunks)
        sizes = bucketing.bucket_sizes(mats)
        return (2 * bucketing.ring_hop_count(sizes, ring),
                2 * bucketing.ring_chain_count(sizes, ring))
""")


def _run(script: str, timeout=900):
    r = subprocess.run([sys.executable, "-c", _PRELUDE + textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "ALL OK" in r.stdout, r.stdout[-2000:]


# --------------------------------------------------------------------------
# equivalence: the ring mean is the same mean
# --------------------------------------------------------------------------
def test_overlapped_pair_matches_blocking_path():
    """The fused overlapped pair must equal two blocking window steps (and
    hence the vmap oracle, which the blocking path is already tested
    against) to fp32 tolerance — CoDA and CODASCA, C ∈ {1, 4}, and a
    second pair so CODASCA's variates are live."""
    _run("""
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    K, I = 8, 3
    for algorithm in ("coda", "codasca"):
        for C in (1, 4):
            ccfg, st0, wb = make_case(K, 2 * I, algorithm=algorithm,
                                      overlap=C)
            base = coda.CoDAConfig(n_workers=K, p_pos=0.7,
                                   algorithm=algorithm)
            exe_on = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                                        donate=False)
            exe_off = coda.make_executor(mcfg, base, "shard_map", mesh=mesh,
                                         donate=False)
            assert exe_on.overlap_pairs and not exe_off.overlap_pairs
            wb2 = as_pair(wb, I)
            wa = jax.tree_util.tree_map(lambda l: l[0], wb2)
            wbb = jax.tree_util.tree_map(lambda l: l[1], wb2)
            s_on, s_off = exe_on.place(st0), exe_off.place(st0)
            for _ in range(2):
                s_on, losses = exe_on.window_pair_step(s_on, wb2, 0.1)
                s_off, l1 = exe_off.window_step(s_off, wa, 0.1)
                s_off, l2 = exe_off.window_step(s_off, wbb, 0.1)
            assert losses.shape == (2 * I, K), losses.shape
            e = max_err(s_on, s_off)
            assert e < 1e-5, (algorithm, C, e)
            le = float(jnp.max(jnp.abs(
                losses - jnp.concatenate([l1, l2], axis=0))))
            assert le < 1e-5, (algorithm, C, le)
            print("OK", algorithm, "C =", C, "err", e)
    print("ALL OK")
    """)


# --------------------------------------------------------------------------
# the compiled schedule: permute chains interleaved with compute
# --------------------------------------------------------------------------
def test_overlapped_hlo_is_chunked_permute_chains():
    """THE overlap acceptance invariant: the compiled window pair contains
    exactly C · 2·(R−1) collective-permutes per ring (2 rings/pair), zero
    all-reduce / all-gather of any kind, and the hops form exactly
    C chains/ring of INDEPENDENT dataflow (the property an async scheduler
    needs to hide late chunks under compute consuming early chunks — a
    de-chunked or cross-chunk-serialized lowering fails it), with the
    second window's dot compute fused between the two rings.  With
    communicate=False the pair is collective-silent."""
    _run("""
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    K, B, C = 8, 8, 4
    for algorithm in ("coda", "codasca"):
        for I in (1, 4):
            ccfg, st0, _ = make_case(K, 2, algorithm=algorithm, overlap=C)
            exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                                     donate=False)
            wb2 = {"features": jax.ShapeDtypeStruct((2, I, K, B, 16),
                                                    jnp.float32),
                   "labels": jax.ShapeDtypeStruct((2, I, K, B), jnp.float32)}
            sts = jax.eval_shape(lambda s: s, st0)
            txt = exe.window_pair_fn(sts, wb2).lower(
                sts, wb2,
                jax.ShapeDtypeStruct((), jnp.float32)).compile().as_text()
            hops, chains = pair_meta(st0, K, C, algorithm)
            # the chain-independence analysis needs the local steps to
            # lower as a while loop (I >= 2); an I=1 window inlines its
            # compute and legitimately chains the rings together
            ops = A.assert_overlapped_window(
                txt, n_hops=hops, n_chains=chains if I > 1 else None)
            assert all(o["op"] == "collective-permute" for o in ops)
            if I > 1:
                # the analysis really counts chunk chains: demanding the
                # de-chunked count must fail for C > 1 chunks
                try:
                    A.assert_overlapped_window(txt, n_hops=hops, n_chains=2)
                    raise SystemExit("chain check accepted wrong count")
                except AssertionError:
                    pass
            silent = exe.window_pair_fn(sts, wb2, communicate=False).lower(
                sts, wb2,
                jax.ShapeDtypeStruct((), jnp.float32)).compile().as_text()
            assert H.collective_ops(silent) == []
            print("OK", algorithm, "I =", I, "hops", hops)
    print("ALL OK")
    """)


# --------------------------------------------------------------------------
# fit(): pair feeding + exposed/overlapped accounting
# --------------------------------------------------------------------------
def test_fit_overlap_pairs_and_accounting():
    """fit() with an overlapping executor must feed window pairs, fall back
    to a single blocking window when a stage's window count is odd, and
    split the per-worker bytes into overlapped (first-of-pair) vs exposed
    (second-of-pair + trailing + stage-end α scalars) such that the total
    equals the classical comm_bytes accounting."""
    _run("""
    from repro.core import schedules
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    K, B = 8, 8
    ccfg, st0, _ = make_case(K, 2, overlap=2)
    exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh)
    key = jax.random.PRNGKey(0)

    def sample_window(k, i):
        ky, kx = jax.random.split(k)
        y = (jax.random.uniform(ky, (i, K, B)) < 0.7).astype(jnp.float32)
        x = jax.random.normal(kx, (i, K, B, 16))
        return {"features": x, "labels": y}

    def sample_ab(k, m):
        wb = sample_window(k, 1)
        return {kk: v[0] for kk, v in wb.items()}

    # T0=12, I0=4 -> stage 1: T=12, 3 windows (1 pair + 1 trailing);
    # stage 2: T=36, 9 windows (4 pairs + 1 trailing)
    sched = schedules.ScheduleConfig(n_workers=K, eta0=0.5, T0=12, I0=4)
    evals = []
    res = coda.fit(key, mcfg, ccfg, sched, 2, sample_window, sample_ab,
                   eval_every=3, eval_fn=lambda s: evals.append(1) or 0.0,
                   executor=exe)
    # per-window cadence survives pair feeding: windows 3 | 3, 6, 9 hit
    # (a pair whose EITHER half lands on the cadence evals once)
    assert len(evals) == 4, len(evals)
    sl = schedules.stages(sched, 2)
    assert res.comm_rounds == coda.comm_rounds(sl)
    mb = coda.model_bytes(res.state)
    # 5 pairs -> 5 overlapped rounds; 5 pair-seconds + 2 trailing exposed
    # window rounds + 2 stage-end f32 alphas
    assert res.overlapped_bytes == 5 * mb, res.overlapped_bytes
    assert res.exposed_bytes == 7 * mb + 2 * 4, res.exposed_bytes
    assert res.exposed_bytes + res.overlapped_bytes == \
        coda.comm_bytes(sl, res.state)
    assert all(np.isfinite(h[2]) for h in res.history)
    # non-overlapping executor: everything exposed
    base = coda.CoDAConfig(n_workers=K, p_pos=0.7)
    res0 = coda.fit(key, mcfg, base, sched, 2, sample_window, sample_ab,
                    executor="vmap")
    assert res0.overlapped_bytes == 0
    assert res0.exposed_bytes == coda.comm_bytes(sl, res0.state)
    print("ALL OK")
    """)


def test_overlap_rejects_multi_axis_worker_partition():
    """A ppermute ring needs one totally-ordered mesh axis: the replica
    policy on a multi-pod mesh lays workers over (pod, data) and must be
    rejected loudly at executor construction."""
    _run("""
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    ccfg, st0, _ = make_case(4, 2, overlap=2)
    try:
        coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh3,
                           policy="replica")
        raise SystemExit("expected ValueError for 2-axis worker partition")
    except ValueError as e:
        assert "ONE mesh axis" in str(e), e
    # fsdp lays workers over (pod,) only: a valid single-axis ring
    ccfg2 = coda.CoDAConfig(n_workers=2, p_pos=0.7, overlap_chunks=2)
    exe = coda.make_executor(mcfg, ccfg2, "shard_map", mesh=mesh3,
                             policy="fsdp")
    assert exe.overlap_pairs
    print("ALL OK")
    """)


# --------------------------------------------------------------------------
# in-process: config guards + ring chunk math (no mesh needed)
# --------------------------------------------------------------------------
def test_config_rejects_overlap_with_int8():
    with pytest.raises(ValueError):
        coda.CoDAConfig(n_workers=4, overlap_chunks=2, avg_compress="int8")
    with pytest.raises(ValueError):
        coda.CoDAConfig(n_workers=4, overlap_chunks=-1)


def test_ring_chunk_and_hop_math():
    ring = bucketing.RingSpec("data", 8, 4)
    # big bucket: all 4 chunks; tiny bucket (< R elems/chunk): 1 chain
    assert bucketing._n_chunks(4096, ring) == 4
    assert bucketing._n_chunks(3, ring) == 1
    assert bucketing.ring_hop_count({jnp.dtype("float32"): 4096}, ring) == \
        4 * 2 * 7
    assert bucketing.ring_hop_count(
        {jnp.dtype("float32"): 4096, jnp.dtype("bfloat16"): 3}, ring) == \
        (4 + 1) * 2 * 7
    assert bucketing.ring_chain_count(
        {jnp.dtype("float32"): 4096, jnp.dtype("bfloat16"): 3}, ring) == 5
    # one participant: no wire, no hops
    assert bucketing.ring_hop_count(
        {jnp.dtype("float32"): 4096}, bucketing.RingSpec("data", 1, 4)) == 0
    with pytest.raises(ValueError):
        bucketing.RingSpec("data", 0, 4)
    # near-even chunk split: never an empty trailing chunk (a ceil split
    # would produce 3,3,3,0 here and XLA could DCE the empty chain)
    assert bucketing._chunk_offsets(9, 4) == [0, 3, 5, 7, 9]
    assert bucketing._chunk_offsets(8, 4) == [0, 2, 4, 6, 8]
    assert bucketing._chunk_offsets(3, 1) == [0, 3]


def test_window_payload_by_dtype():
    """The per-dtype payload helper must split params by their leaf dtypes
    (+ the fp32 a/b/α lane) and double under CODASCA."""
    from repro.configs.base import mlp_config
    mcfg = mlp_config(n_features=16, d=32)
    ccfg = coda.CoDAConfig(n_workers=4, p_pos=0.7,
                           param_dtype=jnp.bfloat16)
    st = coda.init_state(jax.random.PRNGKey(0), mcfg, ccfg)
    by = coda.window_payload_by_dtype(st)
    assert set(by) == {"bf16", "f32"}
    assert sum(by.values()) == coda.window_payload_bytes(st)
    cc = coda.CoDAConfig(n_workers=4, p_pos=0.7, algorithm="codasca",
                         param_dtype=jnp.bfloat16)
    st2 = coda.init_state(jax.random.PRNGKey(0), mcfg, cc)
    by2 = coda.window_payload_by_dtype(st2)
    assert by2["bf16"] == 2 * by["bf16"]
    with pytest.raises(ValueError):
        coda.window_payload_by_dtype(st, "int8")
