"""Masked (partial-participation) window tests on a real 8-device mesh.

The masked merge must be executor-independent: the vmap oracle (wa=()) and
the shard_map executor run the SAME bucketed arithmetic
(``bucketing.masked_average_state`` / ``masked_average_and_refresh``), so a
faulted window is equivalence-testable exactly like the clean one — fp32,
int8-compressed, sketch-carrying, and overlapped (fused pair) variants all
covered below, plus the compiled-HLO contract: the masked window is STILL
exactly one all-reduce per dtype bucket, operand bytes == the documented
payload + the weight lane(s) (``coda.mask_payload_bytes``).

Subprocesses because ``--xla_force_host_platform_device_count`` must be set
before jax initialises (same idiom as tests/test_coda_sharded.py).
"""
import os
import subprocess
import sys
import textwrap

_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import mlp_config
    from repro.core import coda, faults
    from repro.launch import mesh as M

    mcfg = mlp_config(n_features=16, d=32)

    def wb_of(key, I, K, B=4):
        kf, kl = jax.random.split(key)
        y = (jax.random.uniform(kl, (I, K, B)) < 0.6).astype(jnp.float32)
        x = jax.random.normal(kf, (I, K, B, 16)) + 0.3 * (y[..., None] * 2 - 1)
        return {"features": x, "labels": y}

    def fl_of(plan, w, n=1):
        if n == 1:
            u, r = plan.window(w)
            return {"weights": jnp.asarray(u), "resync": jnp.asarray(r)}
        us, rs = zip(*(plan.window(w + j) for j in range(n)))
        return {"weights": jnp.stack([jnp.asarray(x) for x in us]),
                "resync": jnp.stack([jnp.asarray(x) for x in rs])}

    def max_err(a, b):
        return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                         - y.astype(jnp.float32))))
                   for x, y in zip(jax.tree_util.tree_leaves(a),
                                   jax.tree_util.tree_leaves(b)))
""")


def _run(script: str, timeout=900):
    r = subprocess.run(
        [sys.executable, "-c", _PRELUDE + textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "ALL OK" in r.stdout, r.stdout[-2000:]


def test_masked_shard_map_matches_vmap_oracle():
    """3 faulted windows (dropout + stragglers with bounded staleness)
    through both executors: fp32 coda, fp32 codasca, int8 coda, and a
    sketch-carrying state must agree to fp32 tolerance."""
    _run("""
    K, I = 8, 2
    mesh = M.make_worker_mesh(K)
    plan = faults.FaultPlan(n_workers=K, seed=3, dropout=0.4, straggle=0.25,
                            straggle_windows=1, max_staleness=1)
    cases = [
        ("coda fp32", dict(algorithm="coda")),
        ("codasca fp32", dict(algorithm="codasca")),
        ("coda int8", dict(algorithm="coda", avg_compress="int8")),
        ("coda sketch", dict(algorithm="coda", stream_bins=32)),
        ("codasca sketch", dict(algorithm="codasca", stream_bins=32)),
    ]
    for label, kw in cases:
        ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.6, participation=0.6,
                               straggler_prob=0.25, max_staleness=1, **kw)
        key = jax.random.PRNGKey(0)
        st0 = coda.init_state(key, mcfg, ccfg)
        ev = coda.make_executor(mcfg, ccfg, "vmap", donate=False)
        es = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                                donate=False)
        sv, ss = ev.place(st0), es.place(st0)
        for w in range(3):
            b = wb_of(jax.random.PRNGKey(10 + w), I, K)
            fl = fl_of(plan, w)
            sv, lv = ev.window_step(sv, b, jnp.float32(0.3), faults=fl)
            ss, ls = es.window_step(ss, b, jnp.float32(0.3), faults=fl)
        err = max_err(sv, ss)
        assert err < 1e-5, (label, err)
        print(label, "max err", err)
    print("ALL OK")
    """)


def test_masked_sketch_deltas_of_absent_workers_stay_local():
    """Under the masked merge only participants' sketch deltas fold into
    the shared accumulator; an absent worker's ``sk_new`` survives intact
    (to merge at its next participation) while participants' reset."""
    _run("""
    K, I = 8, 2
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.6, participation=0.5,
                           stream_bins=32)
    key = jax.random.PRNGKey(0)
    st0 = coda.init_state(key, mcfg, ccfg)
    ev = coda.make_executor(mcfg, ccfg, "vmap", donate=False)
    b = wb_of(jax.random.PRNGKey(1), I, K)
    u = np.array([1, 0, 1, 0, 1, 0, 1, 0], np.float32)
    fl = {"weights": jnp.asarray(u), "resync": jnp.ones((K,), jnp.float32)}
    # pre-merge sketch rows: local steps only
    local, _ = coda.window_step(mcfg, ccfg, st0, b, jnp.float32(0.3),
                                communicate=False)
    merged, _ = ev.window_step(st0, b, jnp.float32(0.3), faults=fl)
    for side in ("pos", "neg"):
        nl, nm = local["sk_new"][side], merged["sk_new"][side]
        acc = merged["sk_acc"][side]
        for k in range(K):
            if u[k] > 0:   # participant: delta merged, local buffer reset
                assert float(jnp.max(jnp.abs(nm[k]))) == 0.0, (side, k)
            else:          # absent: delta kept bit-for-bit for the next merge
                assert jnp.array_equal(nm[k], nl[k]), (side, k)
        # the shared accumulator got exactly the participants' delta sum,
        # broadcast to every worker row (absent ones resync too: r == 1)
        want = st0["sk_acc"][side][0] + sum(
            nl[k] for k in range(K) if u[k] > 0)
        for k in range(K):
            assert float(jnp.max(jnp.abs(acc[k] - want))) < 1e-4, (side, k)
    print("ALL OK")
    """)


def test_masked_overlap_pair_matches_blocking():
    """The fused overlapped pair under per-window fault vectors ([2, K]
    leaves) must match two blocking masked window steps to fp32 tolerance
    for both algorithms."""
    _run("""
    K, I = 8, 2
    mesh = M.make_worker_mesh(K)
    plan = faults.FaultPlan(n_workers=K, seed=5, dropout=0.4, straggle=0.25,
                            straggle_windows=1, max_staleness=1)
    for algorithm in ("coda", "codasca"):
        ccfg_b = coda.CoDAConfig(n_workers=K, p_pos=0.6, algorithm=algorithm,
                                 participation=0.6, straggler_prob=0.25,
                                 max_staleness=1)
        ccfg_o = coda.CoDAConfig(n_workers=K, p_pos=0.6, algorithm=algorithm,
                                 participation=0.6, straggler_prob=0.25,
                                 max_staleness=1, overlap_chunks=2)
        key = jax.random.PRNGKey(0)
        st0 = coda.init_state(key, mcfg, ccfg_b)
        eb = coda.make_executor(mcfg, ccfg_b, "shard_map", mesh=mesh,
                                donate=False)
        eo = coda.make_executor(mcfg, ccfg_o, "shard_map", mesh=mesh,
                                donate=False)
        wb2 = jax.tree_util.tree_map(
            lambda l: l.reshape((2, I) + l.shape[1:]),
            wb_of(jax.random.PRNGKey(2), 2 * I, K))
        fl2 = fl_of(plan, 0, n=2)
        so, _ = eo.window_pair_step(eo.place(st0), wb2, jnp.float32(0.3),
                                    faults=fl2)
        sb = eb.place(st0)
        for j in range(2):
            b = jax.tree_util.tree_map(lambda l: l[j], wb2)
            fl = jax.tree_util.tree_map(lambda l: l[j], fl2)
            sb, _ = eb.window_step(sb, b, jnp.float32(0.3), faults=fl)
        err = max_err(so, sb)
        assert err < 1e-5, (algorithm, err)
        print(algorithm, "pair vs blocking max err", err)
    print("ALL OK")
    """)


def test_masked_window_hlo_payload_contract():
    """R1 under faults: the compiled masked window still lowers to exactly
    ONE all-reduce per dtype bucket with operand bytes == documented
    payload + the weight lane(s); int8 keeps the (s8 all-gather, f32
    scales+lanes all-gather) pair at K x the masked payload."""
    _run("""
    from repro.analysis import hlo as H
    K, I, B = 8, 2, 4
    mesh = M.make_worker_mesh(K)
    fl = {"weights": jnp.ones((K,), jnp.float32),
          "resync": jnp.ones((K,), jnp.float32)}
    for algorithm in ("coda", "codasca"):
        ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.6, algorithm=algorithm,
                               participation=0.8)
        st0 = coda.init_state(jax.random.PRNGKey(0), mcfg, ccfg)
        exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                                 donate=False)
        b = wb_of(jax.random.PRNGKey(1), I, K, B)
        txt = exe.window_fn(st0, b).lower(
            st0, b, jnp.float32(0.1), fl).compile().as_text()
        payload = coda.window_payload_bytes(st0, masked=True)
        assert payload == coda.window_payload_bytes(st0) \\
            + coda.mask_payload_bytes(st0)
        H.verify_window_payload(
            txt, payload,
            by_dtype=coda.window_payload_by_dtype(st0, masked=True))
        coll = H.collective_bytes(txt)
        assert coll["all-reduce"]["count"] == 1, algorithm
        print(algorithm, "masked payload", payload, "ok")

    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.6, avg_compress="int8",
                           participation=0.8)
    st0 = coda.init_state(jax.random.PRNGKey(0), mcfg, ccfg)
    exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                             donate=False)
    b = wb_of(jax.random.PRNGKey(1), I, K, B)
    txt = exe.window_fn(st0, b).lower(
        st0, b, jnp.float32(0.1), fl).compile().as_text()
    coll = H.collective_bytes(txt)
    gathered = K * coda.window_payload_bytes(st0, "int8", masked=True)
    assert coll["all-reduce"]["count"] == 0
    assert coll["all-gather"]["count"] == 2, coll["all-gather"]
    assert coll["all-gather"]["bytes"] == gathered, (
        coll["all-gather"]["bytes"], gathered)
    print("int8 masked gather pair", gathered, "ok")
    print("ALL OK")
    """)
