"""Sharded-executor tests: vmap-oracle equivalence on 8 forced host devices
(replica + fsdp policies, K=1 / I=1 degenerate cases), int8 compressed
averaging (exactness, error bound, and that the wire payload really is s8),
and communication accounting cross-checked against the all-reduce ops the
compiler emitted.

The mesh-parallel checks run in subprocesses because
``--xla_force_host_platform_device_count`` must be set before jax
initialises its backend, and the parent pytest process has usually already
touched jax by the time this module runs.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import mlp_config
from repro.core import coda, schedules

MCFG = mlp_config(n_features=16, d=32)

_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import mlp_config
    from repro.core import coda, schedules

    mcfg = mlp_config(n_features=16, d=32)

    def make_case(K, I, B=8, compress="", seed=0):
        ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7, avg_compress=compress)
        key = jax.random.PRNGKey(seed)
        st0 = coda.init_state(key, mcfg, ccfg)
        ky, kx = jax.random.split(key)
        y = (jax.random.uniform(ky, (I, K, B)) < 0.7).astype(jnp.float32)
        x = jax.random.normal(kx, (I, K, B, 16)) + 0.3 * (y[..., None] * 2 - 1)
        wb = {"features": x, "labels": y}
        ab = {"features": x[0], "labels": y[0]}
        return ccfg, st0, wb, ab

    def assert_trees_close(got, want, tol, label):
        for (p, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(got)[0],
                                  jax.tree_util.tree_flatten_with_path(want)[0]):
            err = float(jnp.max(jnp.abs(a - b)))
            assert err < tol, (label, jax.tree_util.keystr(p), err)
""")


def _run(script: str, timeout=900):
    r = subprocess.run([sys.executable, "-c", _PRELUDE + textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "ALL OK" in r.stdout, r.stdout[-2000:]


# --------------------------------------------------------------------------
# vmap-oracle equivalence on a real 8-device mesh
# --------------------------------------------------------------------------
def test_shard_map_matches_vmap_oracle():
    """window_step + stage_end through shard_map must match the single-device
    oracle to fp32 tolerance: replica (K=8 sharded over 8 devices) and fsdp
    (K=2 over the pod axis) policies, plus the K=1 (PPD-SG) and I=1
    (NP-PPD-SG) degenerate cases."""
    _run("""
    mesh2 = jax.make_mesh((8, 1), ("data", "model"))
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cases = [
        ("replica K=8 I=4", 8, 4, "replica", mesh2, ("data",)),
        ("replica K=1 (PPD-SG)", 1, 3, "replica", mesh2, ()),
        ("replica I=1 (NP-PPD-SG)", 8, 1, "replica", mesh2, ("data",)),
        ("fsdp multi-pod K=2", 2, 3, "fsdp", mesh3, ("pod",)),
    ]
    for label, K, I, policy, mesh, want_wa in cases:
        ccfg, st0, wb, ab = make_case(K, I)
        exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                                 policy=policy, donate=False)
        assert exe.worker_axes == want_wa, (label, exe.worker_axes)
        st1, losses = exe.window_step(exe.place(st0), wb, 0.1)
        st2 = exe.stage_end(st1, ab)
        r1, rl = coda.window_step(mcfg, ccfg, st0, wb, 0.1)
        r2 = coda.stage_end(mcfg, ccfg, r1, ab, resync=False)
        assert losses.shape == (I, K), (label, losses.shape)
        assert_trees_close(st1, r1, 1e-5, label + "/window")
        assert_trees_close(st2, r2, 1e-5, label + "/stage")
        np.testing.assert_allclose(np.asarray(jnp.mean(losses, axis=1)),
                                   np.asarray(rl), atol=1e-5)
        print("OK", label)
    print("ALL OK")
    """)


def test_shard_map_int8_matches_oracle_and_ships_s8():
    """The compressed path must match the vmap oracle's int8 averaging AND
    actually put int8 on the wire: the lowered window HLO contains no fp32
    all-reduce of the model — only the s8 payload all-gather plus the fp32
    per-tensor scales."""
    _run("""
    from repro.analysis import hlo as H
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    K, I = 8, 2
    ccfg, st0, wb, ab = make_case(K, I, compress="int8")
    exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh, donate=False)
    st1, _ = exe.window_step(exe.place(st0), wb, 0.1)
    r1, _ = coda.window_step(mcfg, ccfg, st0, wb, 0.1)
    assert_trees_close(st1, r1, 1e-5, "int8/window")

    txt = exe.window_fn(st0, wb).lower(st0, wb, jnp.float32(0.1)) \\
             .compile().as_text()
    ops = H.collective_ops(txt)
    assert all(o["op"] == "all-gather" for o in ops), ops
    by_dtype = {}
    for o in ops:
        for dt, b in o["by_dtype"].items():
            by_dtype[dt] = by_dtype.get(dt, 0) + b
    n_elems = sum(l.size // K for l in
                  jax.tree_util.tree_leaves(st0["params"])) + 3
    n_tensors = len(jax.tree_util.tree_leaves(st0["params"])) + 3
    assert by_dtype.get("s8") == K * n_elems, by_dtype        # 1 B/elem wire
    assert by_dtype.get("f32") == K * n_tensors * 4, by_dtype  # scales only
    # gathered bytes / K == what one worker ships == model_bytes(int8)
    assert sum(by_dtype.values()) // K == coda.model_bytes(st0, "int8")
    print("ALL OK")
    """)


# --------------------------------------------------------------------------
# communication accounting vs the compiler
# --------------------------------------------------------------------------
def test_comm_accounting_matches_lowered_hlo():
    """comm_rounds / model_bytes / comm_bytes must agree with the compiled
    artifact: one compiled window = exactly one cross-worker all-reduce whose
    bytes equal model_bytes(state); communicate=False = zero collectives; a
    stage boundary ships one f32 scalar.  Checked over several (T, I)
    schedules."""
    _run("""
    from repro.analysis import hlo as H
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    K, B = 8, 8
    ccfg, st0, _, ab = make_case(K, 1, B=B)
    exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh, donate=False)

    def window_ops(I, communicate=True):
        wb = {"features": jax.ShapeDtypeStruct((I, K, B, 16), jnp.float32),
              "labels": jax.ShapeDtypeStruct((I, K, B), jnp.float32)}
        sts = jax.eval_shape(lambda s: s, st0)
        txt = exe.window_fn(sts, wb, communicate=communicate).lower(
            sts, wb, jax.ShapeDtypeStruct((), jnp.float32)).compile().as_text()
        return H.collective_ops(txt)

    mb = coda.model_bytes(st0)
    for I in (1, 4, 8):
        ops = window_ops(I)
        ars = [o for o in ops if o["op"] == "all-reduce"]
        assert len(ops) == len(ars) == 1, (I, ops)   # exactly ONE all-reduce
        assert ars[0]["bytes"] == mb, (I, ars[0], mb)
        assert "0,1,2,3,4,5,6,7" in ars[0]["replica_groups"], ars[0]
    assert window_ops(4, communicate=False) == []    # I local steps: silent

    sts = jax.eval_shape(lambda s: s, st0)
    stage_txt = exe.stage_fn(sts, ab).lower(sts, ab).compile().as_text()
    stage_ops = H.collective_ops(stage_txt)
    assert len(stage_ops) == 1 and stage_ops[0]["op"] == "all-reduce"
    assert stage_ops[0]["bytes"] == 4, stage_ops     # one fp32 scalar

    for T0, I0, n_stages in [(6, 1, 2), (8, 4, 2), (30, 8, 3)]:
        sched = schedules.ScheduleConfig(n_workers=K, eta0=0.5, T0=T0, I0=I0)
        sl = schedules.stages(sched, n_stages)
        n_windows = sum(-(-s.T // s.I) for s in sl)
        assert coda.comm_rounds(sl) == n_windows + n_stages
        hlo_total = n_windows * mb + n_stages * 4
        assert hlo_total == coda.comm_bytes(sl, st0), (T0, I0)
    print("ALL OK")
    """)


def test_mixed_dtype_window_payload_verifies_per_dtype_bucket():
    """bf16 params + the fp32 a/b/α (and the model's fp32 score_head bias)
    make the bucketed averaging emit one all-reduce PER DTYPE — two ops,
    not one.  ``verify_window_payload`` must accept that as the documented
    layout (one collective per dtype bucket, total == payload, per-dtype
    bytes == ``window_payload_by_dtype``) instead of failing spuriously,
    while still rejecting a forced count=1 and a wrong per-dtype split."""
    _run("""
    from repro.analysis import audit as A
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    K, I, B = 8, 2, 8
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7,
                           param_dtype=jnp.bfloat16)
    st0 = coda.init_state(jax.random.PRNGKey(0), mcfg, ccfg)
    dts = {l.dtype for l in jax.tree_util.tree_leaves(st0["params"])}
    assert dts == {jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float32)}, dts
    exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                             donate=False)
    wb = {"features": jax.ShapeDtypeStruct((I, K, B, 16), jnp.float32),
          "labels": jax.ShapeDtypeStruct((I, K, B), jnp.float32)}
    sts = jax.eval_shape(lambda s: s, st0)
    txt = exe.window_fn(sts, wb).lower(
        sts, wb, jax.ShapeDtypeStruct((), jnp.float32)).compile().as_text()

    payload = coda.window_payload_bytes(st0)
    by_dtype = coda.window_payload_by_dtype(st0)
    assert set(by_dtype) == {"bf16", "f32"}
    ops = A.assert_window_payload(txt, payload, by_dtype=by_dtype)
    assert len(ops) == 2, ops           # one all-reduce per dtype bucket
    try:
        A.assert_window_payload(txt, payload, count=1)
        raise SystemExit("count=1 must fail on a mixed-dtype window")
    except AssertionError:
        pass
    try:
        A.assert_window_payload(txt, payload,
                                by_dtype={"bf16": payload, "f32": 0})
        raise SystemExit("wrong per-dtype split must fail")
    except AssertionError:
        pass
    # the bf16 sharded window still matches the vmap oracle
    key = jax.random.PRNGKey(1)
    ky, kx = jax.random.split(key)
    y = (jax.random.uniform(ky, (I, K, B)) < 0.7).astype(jnp.float32)
    x = jax.random.normal(kx, (I, K, B, 16))
    wbr = {"features": x, "labels": y}
    st1, _ = exe.window_step(exe.place(st0), wbr, 0.1)
    r1, _ = coda.window_step(mcfg, ccfg, st0, wbr, 0.1)
    assert_trees_close(
        {k: v.astype(jnp.float32) if hasattr(v, "astype") else v
         for k, v in st1.items() if k in ("a", "b", "alpha")},
        {k: v for k, v in r1.items() if k in ("a", "b", "alpha")},
        1e-5, "bf16/scalars")
    for (p, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(st1["params"])[0],
            jax.tree_util.tree_flatten_with_path(r1["params"])[0]):
        err = float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                    b.astype(jnp.float32))))
        assert err < 2e-2, (jax.tree_util.keystr(p), err)  # bf16 tolerance
    print("ALL OK")
    """)


def test_executor_instance_survives_changing_window_length():
    """Regression for the ``_fns`` cache: its key is (treedef, ndim) only,
    so two window lengths I₁ ≠ I₂ (same rank, different shape) hit the
    SAME cache entry and rely on jit retracing under it.  One executor
    instance driven at I=2 then I=5, with both ``communicate`` flags, must
    keep matching the oracle — a stale lowered shape would either crash or
    silently produce wrong results."""
    _run("""
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    K = 8
    ccfg, st0, _, _ = make_case(K, 2)
    exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                             donate=False)
    st = exe.place(st0)
    rt = st0
    for I, communicate in [(2, True), (5, True), (2, False), (5, False),
                           (3, True)]:
        _, _, wb, _ = make_case(K, I, seed=I)
        st, losses = exe.window_step(st, wb, 0.1, communicate=communicate)
        rt, rl = coda.window_step(mcfg, ccfg, rt, wb, 0.1,
                                  communicate=communicate)
        assert losses.shape == (I, K), (I, losses.shape)
        assert_trees_close(st, rt, 1e-5, f"I={I} comm={communicate}")
        np.testing.assert_allclose(np.asarray(jnp.mean(losses, axis=1)),
                                   np.asarray(rl), atol=1e-5)
        print("OK", I, communicate)
    # the cache really is shared per (tag, treedef, ndim): 2 entries
    # (communicate True/False), not one per window length
    assert len(exe._fns) == 2, len(exe._fns)
    print("ALL OK")
    """)


# --------------------------------------------------------------------------
# int8 averaging properties (single-device oracle; no mesh needed)
# --------------------------------------------------------------------------
def _toy_state(key, K, shapes=((4, 3), (5,))):
    ks = jax.random.split(key, len(shapes) + 3)
    params = {f"w{i}": jax.random.normal(k, (K,) + s)
              for i, (k, s) in enumerate(zip(ks, shapes))}
    z = lambda k: jax.random.normal(k, (K,))
    return {"params": params,
            "duals": {"a": z(ks[-3]), "b": z(ks[-2]), "alpha": z(ks[-1])},
            "ref_params": params,
            "ref_duals": {"a": jnp.zeros((K,)), "b": jnp.zeros((K,))}}


@settings(max_examples=15, deadline=None)
@given(c=st.floats(-3.0, 3.0), spread=st.floats(0.0, 2.0),
       seed=st.integers(0, 1000))
def test_int8_average_exact_on_uniform_tensors(c, spread, seed):
    """When every worker's tensor is per-tensor uniform, quantization maps
    each value to exactly ±127 of its own scale — the int8 average equals
    the exact average to fp32 precision."""
    K = 4
    cs = c + spread * jnp.arange(K)  # per-worker constants
    state = _toy_state(jax.random.PRNGKey(seed), K)
    state["params"] = {
        "w0": jnp.broadcast_to(cs[:, None, None], (K, 4, 3)).copy()}
    state["duals"] = {"a": cs.astype(jnp.float32),
                      "b": -cs.astype(jnp.float32),
                      "alpha": cs.astype(jnp.float32)}
    got = coda.average(state, compress="int8")
    want = coda.average(state)
    for ka, kb in zip(jax.tree_util.tree_leaves(got),
                      jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(ka), np.asarray(kb), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(scale=st.floats(0.01, 10.0), seed=st.integers(0, 1000))
def test_int8_average_error_bounded_by_quantization_step(scale, seed):
    """|int8-avg − exact-avg| ≤ one quantization step of the max-abs scale
    (elementwise error ≤ scale_k/2 per worker; averaging cannot grow it)."""
    K = 4
    state = _toy_state(jax.random.PRNGKey(seed), K)
    state["params"] = jax.tree_util.tree_map(lambda x: x * scale,
                                             state["params"])
    got = coda.average(state, compress="int8")
    want = coda.average(state)
    for leaf_q, leaf_x, leaf_o in zip(
            jax.tree_util.tree_leaves(got["params"]),
            jax.tree_util.tree_leaves(state["params"]),
            jax.tree_util.tree_leaves(want["params"])):
        step = float(jnp.max(jnp.abs(leaf_x)) / 127.0)
        err = float(jnp.max(jnp.abs(leaf_q - leaf_o)))
        assert err <= step + 1e-7, (err, step)


def test_int8_sharded_bucket_matches_oracle_without_mesh():
    """The bucketed averaging helper (what shard_map runs per shard) must
    equal coda.average(compress='int8') even in its degenerate no-mesh form
    (wa=(), K_loc=K)."""
    from repro.core import coda_sharded
    state = _toy_state(jax.random.PRNGKey(3), 4)
    got = coda_sharded._bucketed_average(state, (), "int8")
    want = coda.average(state, compress="int8")
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# --------------------------------------------------------------------------
# driver / executor surface
# --------------------------------------------------------------------------
def test_fit_vmap_executor_donated_driver():
    """The jit-once donated-buffer driver must run multi-stage training
    without donation aliasing errors and keep the comm accounting."""
    from repro.data import DataConfig, ShardedDataset
    key = jax.random.PRNGKey(0)
    K = 4
    ds = ShardedDataset(key, DataConfig(kind="features", n_features=16),
                        1024, K, target_p=0.7)
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=ds.p_pos)
    sched = schedules.ScheduleConfig(n_workers=K, eta0=0.5, T0=8, I0=4)
    res = coda.fit(key, MCFG, ccfg, sched, 2,
                   sample_window=lambda k, i: ds.sample_window(k, i, 16),
                   sample_alpha_batch=lambda k, m: ds.sample_alpha_batch(k, m),
                   executor="vmap")
    sl = schedules.stages(sched, 2)
    assert res.comm_rounds == coda.comm_rounds(sl)
    assert res.iterations == sum(s.T for s in sl)
    assert all(np.isfinite(h[2]) for h in res.history)


def test_make_executor_rejects_bad_flags():
    ccfg = coda.CoDAConfig(n_workers=2)
    try:
        coda.make_executor(MCFG, ccfg, "shard_map")
        raise AssertionError("expected ValueError for missing mesh")
    except ValueError:
        pass
    try:
        coda.make_executor(MCFG, ccfg, "pmap")
        raise AssertionError("expected ValueError for unknown executor")
    except ValueError:
        pass
