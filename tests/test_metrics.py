"""Tests for the streaming-metrics subsystem (repro/metrics/streaming.py)
and its integration into the training loop.

The load-bearing claims, each pinned here:

  * the sketch AUC/pAUC agree with an O(n^2) pairwise oracle within the
    sketch's own computable ``resolution`` bound (property-based over
    random streams, sizes, and bin counts — the bound is vs the TRUE value,
    so a float64 oracle needs no fp slack);
  * the bound is monotone non-increasing under dyadic bin refinement, and
    the realised error shrinks with bins;
  * ``merge`` is associative, commutative, has ``empty_sketch`` as
    identity, and merging per-shard sketches is bitwise identical to
    sketching the stream in one pass (the property the window wire relies
    on);
  * the host (NumPy) and traced (jnp ``update_counts``) binning paths
    produce identical counts — the training sketch and the host-side
    serving sketch histogram the same way;
  * the ``exact`` backend is numerically identical to the old
    ``objective.roc_auc`` / ``objective.partial_auc`` path it replaced, and
    ``Objective.eval_metric`` now raises a clear migration error;
  * with ``CoDAConfig.stream_bins`` on, a vmap training window accumulates
    exactly the scores its local steps computed (replay oracle), replicates
    the accumulator across worker rows, zeroes the deltas, and the payload
    accounting reports exactly the 2*bins*4-byte delta;
  * the sharded executor (subprocess, 8 forced host devices) matches the
    vmap oracle bitwise on the sketch counts for coda AND codasca, and
    ``analysis.hlo.verify_window_payload`` asserts the compiled window's
    collective bytes split exactly into baseline + sketch delta (and stays
    at baseline with the hook off).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import mlp_config
from repro.core import coda, objective
from repro.metrics import streaming


# --------------------------------------------------------------------------
# O(n^2) oracles (float64: the bound is vs the true value)
# --------------------------------------------------------------------------
def _pairwise_auc(scores, labels):
    s = np.asarray(scores, np.float64).ravel()
    y = np.asarray(labels, np.float64).ravel()
    pos, neg = s[y > 0.5], s[y <= 0.5]
    if not len(pos) or not len(neg):
        return 0.0
    d = pos[:, None] - neg[None, :]
    return float(((d > 0) + 0.5 * (d == 0)).mean())


def _pairwise_pauc(scores, labels, beta):
    # hardest ceil(beta*N) negatives; ties at the k-boundary are harmless
    # (tied values contribute identically whichever side of the cut)
    s = np.asarray(scores, np.float64).ravel()
    y = np.asarray(labels, np.float64).ravel()
    pos, neg = s[y > 0.5], np.sort(s[y <= 0.5])[::-1]
    if not len(pos) or not len(neg):
        return 0.0
    hard = neg[:max(1, int(np.ceil(beta * len(neg))))]
    d = pos[:, None] - hard[None, :]
    return float(((d > 0) + 0.5 * (d == 0)).mean())


def _stream(seed, n):
    rng = np.random.RandomState(seed)
    y = (rng.uniform(size=n) < 0.65).astype(np.float32)
    s = np.where(y > 0.5, rng.normal(0.8, 1.5, n),
                 rng.normal(-0.6, 1.4, n)).astype(np.float32)
    return s, y


# --------------------------------------------------------------------------
# sketch vs oracle: within the computable bound (property-based)
# --------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=2, max_value=160),
       bins=st.sampled_from([4, 16, 64, 256]))
def test_sketch_auc_within_resolution_of_pairwise_oracle(seed, n, bins):
    s, y = _stream(seed, n)
    met = streaming.make_metric("auc", "sketch", bins=bins)
    sk = met.update(met.init(), s, y)
    assert abs(met.finalize(sk) - _pairwise_auc(s, y)) \
        <= met.resolution(sk) + 1e-9


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=2, max_value=160),
       bins=st.sampled_from([4, 16, 64, 256]),
       beta=st.sampled_from([0.1, 0.3, 0.5, 1.0]))
def test_sketch_pauc_within_resolution_of_pairwise_oracle(seed, n, bins, beta):
    s, y = _stream(seed, n)
    met = streaming.make_metric("pauc", "sketch", beta=beta, bins=bins)
    sk = met.update(met.init(), s, y)
    assert abs(met.finalize(sk) - _pairwise_pauc(s, y, beta)) \
        <= met.resolution(sk) + 1e-9


def test_error_and_bound_shrink_with_bins():
    s, y = _stream(7, 4000)
    truth = _pairwise_auc(s, y)
    prev_bound = np.inf
    errs = {}
    for bins in (16, 64, 256, 1024, 4096):
        met = streaming.make_metric("auc", "sketch", bins=bins)
        sk = met.update(met.init(), s, y)
        res = met.resolution(sk)
        errs[bins] = abs(met.finalize(sk) - truth)
        assert errs[bins] <= res + 1e-9
        assert res <= prev_bound + 1e-12, "bound grew under refinement"
        prev_bound = res
    assert errs[4096] < errs[16]
    assert prev_bound < 1e-2  # 4096 bins resolve a 4k stream tightly


def test_degenerate_conventions_match_exact_backend():
    for backend in ("exact", "sketch"):
        met = streaming.make_metric("auc", backend, bins=32)
        assert met.finalize(met.init()) == 0.0                      # empty
        one = met.update(met.init(), [0.3, 0.4], [1.0, 1.0])
        assert met.finalize(one) == 0.0                             # one class
        ties = met.update(met.init(), [0.5] * 6, [1, 0, 1, 0, 1, 0])
        assert met.finalize(ties) == pytest.approx(0.5)             # all ties


# --------------------------------------------------------------------------
# merge algebra: the property the window wire relies on
# --------------------------------------------------------------------------
def _eq(a, b):
    return (np.array_equal(a.pos, b.pos) and np.array_equal(a.neg, b.neg)
            and a.lo == b.lo and a.hi == b.hi)


def test_merge_is_associative_commutative_with_identity():
    parts = [streaming.update(streaming.empty_sketch(64), *_stream(i, 50))
             for i in range(3)]
    a, b, c = parts
    assert _eq(streaming.merge(a, b), streaming.merge(b, a))
    assert _eq(streaming.merge(streaming.merge(a, b), c),
               streaming.merge(a, streaming.merge(b, c)))
    assert _eq(streaming.merge(a, streaming.empty_sketch(64)), a)
    with pytest.raises(ValueError, match="incompatible"):
        streaming.merge(a, streaming.empty_sketch(32))


def test_merge_of_shards_equals_one_stream():
    s, y = _stream(11, 999)
    whole = streaming.update(streaming.empty_sketch(128), s, y)
    shards = [streaming.update(streaming.empty_sketch(128), si, yi)
              for si, yi in zip(np.array_split(s, 7), np.array_split(y, 7))]
    acc = shards[0]
    for sh in shards[1:]:
        acc = streaming.merge(acc, sh)
    assert _eq(acc, whole)


def test_host_and_traced_binning_agree():
    s, y = _stream(3, 777)
    host = streaming.update(streaming.empty_sketch(64, -8.0, 8.0), s, y)
    pos, neg = streaming.update_counts(
        jnp.zeros(64, jnp.float32), jnp.zeros(64, jnp.float32),
        jnp.asarray(s), jnp.asarray(y), -8.0, 8.0)
    assert np.array_equal(np.asarray(pos), host.pos)
    assert np.array_equal(np.asarray(neg), host.neg)


# --------------------------------------------------------------------------
# Metric API: exact backend identity + the eval_metric migration error
# --------------------------------------------------------------------------
def test_exact_backend_identical_to_old_objective_path():
    s, y = _stream(5, 321)
    assert streaming.make_metric("auc", "exact").compute(s, y) \
        == float(objective.roc_auc(s, y))
    assert streaming.make_metric("pauc", "exact", beta=0.3).compute(s, y) \
        == float(objective.partial_auc(s, y, 0.3))


def test_exact_backend_chunked_updates_match_one_shot():
    s, y = _stream(9, 300)
    met = streaming.make_metric("auc", "exact")
    state = met.init()
    for si, yi in zip(np.array_split(s, 5), np.array_split(y, 5)):
        state = met.update(state, si, yi)
    assert met.finalize(state) == met.compute(s, y)
    assert met.state_bytes(state) == s.nbytes + y.nbytes


def test_objective_metric_factory_and_eval_metric_removal():
    auc_obj = objective.AUCObjective()
    assert auc_obj.metric("exact").name == "auc"
    dro = objective.PAUCDROObjective(beta=0.25)
    met = dro.metric("sketch", bins=64)
    assert met.name == "pauc" and met.beta == 0.25 and met.bins == 64
    with pytest.raises(AttributeError, match="Objective.metric"):
        auc_obj.eval_metric
    with pytest.raises(ValueError, match="unknown metric kind"):
        streaming.make_metric("f1", "exact")
    with pytest.raises(ValueError, match="unknown metric backend"):
        streaming.make_metric("auc", "approx")


# --------------------------------------------------------------------------
# training integration (vmap): replay oracle + payload accounting
# --------------------------------------------------------------------------
def _window_case(K=4, I=3, B=8, bins=16, seed=0, **kw):
    mcfg = mlp_config(n_features=16, d=32)
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7, stream_bins=bins, **kw)
    key = jax.random.PRNGKey(seed)
    st0 = coda.init_state(key, mcfg, ccfg)
    ky, kx = jax.random.split(key)
    y = (jax.random.uniform(ky, (I, K, B)) < 0.7).astype(jnp.float32)
    x = jax.random.normal(kx, (I, K, B, 16)) + 0.3 * (y[..., None] * 2 - 1)
    return mcfg, ccfg, st0, {"features": x, "labels": y}


def test_window_sketch_matches_score_replay_oracle():
    """The in-training sketch holds EXACTLY the histogram of the scores the
    local steps computed: replay the window step by step with
    ``grad_step_scores`` (same params trajectory — the sketch never feeds
    back into the updates) and histogram the scores by hand."""
    mcfg, ccfg, st0, wb = _window_case()
    state, _ = coda.window_step(mcfg, ccfg, st0, wb, jnp.float32(0.1))

    oracle = streaming.empty_sketch(ccfg.stream_bins, *ccfg.stream_range)
    replay = st0
    for i in range(wb["labels"].shape[0]):
        batch = {k: v[i] for k, v in wb.items()}
        _, _, hs = coda.grad_step_scores(mcfg, ccfg, replay, batch)
        oracle = streaming.update(oracle, np.asarray(hs),
                                  np.asarray(batch["labels"]))
        replay, _ = coda.local_step(mcfg, ccfg, replay, batch,
                                    jnp.float32(0.1))

    got = streaming.sketch_from_rows(state["sk_acc"], *ccfg.stream_range)
    assert np.array_equal(got.pos, oracle.pos)
    assert np.array_equal(got.neg, oracle.neg)
    I, K, B = wb["labels"].shape
    assert got.count == I * K * B
    # the accumulator is replicated across worker rows, the deltas are reset
    for leaf in (state["sk_acc"]["pos"], state["sk_acc"]["neg"]):
        assert np.array_equal(np.asarray(leaf),
                              np.broadcast_to(np.asarray(leaf[0]), leaf.shape))
    assert not np.asarray(state["sk_new"]["pos"]).any()
    assert not np.asarray(state["sk_new"]["neg"]).any()


def test_window_sketch_accumulates_across_windows_and_auc_within_bound():
    mcfg, ccfg, st0, wb = _window_case(bins=128)
    state = st0
    seen_s, seen_y = [], []
    for _w in range(3):
        replay = state
        for i in range(wb["labels"].shape[0]):
            batch = {k: v[i] for k, v in wb.items()}
            _, _, hs = coda.grad_step_scores(mcfg, ccfg, replay, batch)
            seen_s.append(np.asarray(hs).ravel())
            seen_y.append(np.asarray(batch["labels"]).ravel())
            replay, _ = coda.local_step(mcfg, ccfg, replay, batch,
                                        jnp.float32(0.1))
        state, _ = coda.window_step(mcfg, ccfg, state, wb, jnp.float32(0.1))
    sk = streaming.sketch_from_rows(state["sk_acc"], *ccfg.stream_range)
    I, K, B = wb["labels"].shape
    assert sk.count == 3 * I * K * B
    met = streaming.SketchMetric(bins=ccfg.stream_bins)
    truth = _pairwise_auc(np.concatenate(seen_s), np.concatenate(seen_y))
    assert abs(met.finalize(sk) - truth) <= met.resolution(sk) + 1e-9


def test_streaming_payload_accounting():
    mcfg, ccfg, st0, _ = _window_case(bins=16)
    mcfg2, base_cfg, base_st, _ = _window_case(bins=0)
    delta = 2 * 16 * 4
    assert coda.streaming_payload_bytes(st0) == delta
    assert coda.streaming_payload_bytes(base_st) == 0
    assert coda.window_payload_bytes(st0) == \
        coda.window_payload_bytes(base_st) + delta
    by_dtype = coda.window_payload_by_dtype(st0)
    assert by_dtype["f32"] == coda.window_payload_by_dtype(base_st)["f32"] + delta
    # CODASCA doubles the model payload but NOT the sketch delta (the deltas
    # ride the wire once; the correction variates don't histogram anything)
    _, _, sca_st, _ = _window_case(bins=16, algorithm="codasca")
    _, _, sca_base, _ = _window_case(bins=0, algorithm="codasca")
    assert coda.window_payload_bytes(sca_st) == \
        coda.window_payload_bytes(sca_base) + delta
    with pytest.raises(ValueError, match="stream_bins"):
        coda.CoDAConfig(n_workers=2, p_pos=0.7, stream_bins=-1)
    with pytest.raises(ValueError, match="stream"):
        coda.CoDAConfig(n_workers=2, p_pos=0.7, stream_bins=16,
                        stream_range=(2.0, -2.0))


def test_verify_window_payload_split_validation():
    from repro.analysis import audit as A
    with pytest.raises(ValueError, match="go together"):
        A.assert_window_payload("", 100, baseline_bytes=90)


# --------------------------------------------------------------------------
# sharded path (subprocess: 8 forced host devices)
# --------------------------------------------------------------------------
_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.analysis import audit as A
    from repro.configs.base import mlp_config
    from repro.core import coda, codasca
    from repro.metrics import streaming
    mcfg = mlp_config(n_features=16, d=32)

    def make_case(K, I, B=8, seed=0, **kw):
        ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7, **kw)
        key = jax.random.PRNGKey(seed)
        st0 = coda.init_state(key, mcfg, ccfg)
        ky, kx = jax.random.split(key)
        y = (jax.random.uniform(ky, (I, K, B)) < 0.7).astype(jnp.float32)
        x = jax.random.normal(kx, (I, K, B, 16)) + 0.3 * (y[..., None] * 2 - 1)
        return ccfg, st0, {"features": x, "labels": y}
""")


def _run_sub(script: str, timeout=900):
    r = subprocess.run([sys.executable, "-c",
                        _PRELUDE + textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "ALL OK" in r.stdout, r.stdout[-2000:]


def test_shard_map_streaming_eval_matches_oracle_and_payload_delta():
    """The CI matrix's streaming-eval case: with ``stream_bins`` on, the
    sharded executor's window (coda AND codasca) lands the SAME sketch
    counts as the vmap oracle — the merge rides the one window all-reduce
    pre-scaled so the collective's mean is the exact integer sum — and
    ``verify_window_payload`` asserts the collective bytes split exactly
    into the no-sketch baseline plus the 2*bins*4 sketch delta.  With the
    hook off the payload is byte-identical to the baseline."""
    _run_sub("""
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    K, I, BINS = 8, 3, 16
    delta = 2 * BINS * 4
    for label, kw in [("coda", {}), ("codasca", dict(algorithm="codasca"))]:
        base_cfg, base_st, wb = make_case(K, I, **kw)
        ccfg, st0, wb = make_case(K, I, stream_bins=BINS, **kw)
        wstep = codasca.window_step if ccfg.algorithm == "codasca" \\
            else coda.window_step
        exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                                 donate=False)
        st, rt = exe.place(st0), st0
        for _ in range(2):
            st, _ = exe.window_step(st, wb, 0.1)
            rt, _ = wstep(mcfg, ccfg, rt, wb, 0.1)
        for f in ("pos", "neg"):
            assert np.array_equal(np.asarray(st["sk_acc"][f]),
                                  np.asarray(rt["sk_acc"][f])), (label, f)
            assert not np.asarray(st["sk_new"][f]).any(), (label, f)
        n = float(np.asarray(st["sk_acc"]["pos"][0]).sum()
                  + np.asarray(st["sk_acc"]["neg"][0]).sum())
        assert n == 2 * I * K * 8, n

        # payload: baseline + exactly the sketch delta on the wire
        base = coda.window_payload_bytes(base_st)
        payload = coda.window_payload_bytes(st0)
        assert payload == base + delta
        txt = exe.window_fn(st0, wb).lower(
            st0, wb, jnp.float32(0.1)).compile().as_text()
        A.assert_window_payload(txt, payload, baseline_bytes=base,
                                delta_bytes=delta)
        # hook off: the compiled window is byte-identical to the baseline
        bexe = coda.make_executor(mcfg, base_cfg, "shard_map", mesh=mesh,
                                  donate=False)
        btxt = bexe.window_fn(base_st, wb).lower(
            base_st, wb, jnp.float32(0.1)).compile().as_text()
        A.assert_window_payload(btxt, base)
        print("OK", label, "payload", payload, "=", base, "+", delta)
    print("ALL OK")
    """)


# --------------------------------------------------------------------------
# saturation counters + per-worker skew readout (PR 10 satellites)
# --------------------------------------------------------------------------
def test_saturation_counters_count_and_merge_exactly():
    """Host-side sketches count every clipped score exactly; ``merge`` sums
    the counters; ``edge_mass`` upper-bounds ``clipped``."""
    sk = streaming.empty_sketch(32, -1.0, 1.0)
    s = np.array([-5.0, -1.0001, 0.0, 0.5, 1.0, 7.0], np.float32)
    y = np.array([1, 0, 1, 0, 1, 0], np.float32)
    sk = streaming.update(sk, s, y)
    assert sk.under == 2 and sk.over == 2      # -5, -1.0001 | 1.0, 7 (hi incl)
    assert sk.clipped == pytest.approx(4 / 6)
    assert sk.edge_mass >= sk.clipped
    other = streaming.update(streaming.empty_sketch(32, -1.0, 1.0),
                             np.array([3.0], np.float32),
                             np.array([1.0], np.float32))
    merged = streaming.merge(sk, other)
    assert merged.under == 2 and merged.over == 3
    # device-lifted sketches carry NO counters (they never ride the wire)
    lifted = streaming.sketch_from_rows(
        {"pos": sk.pos[None], "neg": sk.neg[None]}, -1.0, 1.0)
    assert lifted.under == 0 and lifted.over == 0 and lifted.clipped == 0.0
    assert lifted.edge_mass == sk.edge_mass


def test_clip_warning_counter_and_edge_mass_paths():
    from repro.metrics import report

    met = streaming.make_metric("auc", "sketch", bins=128)
    rng = np.random.default_rng(0)
    s = rng.normal(0.0, 1.0, 400).astype(np.float32)
    y = (rng.random(400) < 0.5).astype(np.float32)

    # in-range stream: no warning
    ok = streaming.update(streaming.empty_sketch(128, -8.0, 8.0), s, y)
    assert report._clip_warning(ok) is None
    line = report.metric_line("eval", 1, met, ok)
    assert "WARN" not in line

    # >1% of scores outside the range: the exact counter fires
    clipped = streaming.update(streaming.empty_sketch(128, -0.5, 0.5), s, y)
    warn = report._clip_warning(clipped)
    assert warn and "clipped=" in warn and "widen the sketch range" in warn
    assert "WARN" in report.metric_line("eval", 1, met, clipped)

    # device-lifted twin (counters zeroed): the edge-mass fallback fires
    lifted = streaming.ScoreSketch(clipped.pos, clipped.neg, -0.5, 0.5)
    warn = report._clip_warning(lifted)
    assert warn and "edge-bin mass=" in warn
    # ... but not with few bins, where end bins legitimately hold mass
    coarse = streaming.ScoreSketch(clipped.pos.reshape(8, 16).sum(1),
                                   clipped.neg.reshape(8, 16).sum(1),
                                   -0.5, 0.5)
    assert report._clip_warning(coarse) is None


def test_worker_skew_line_reports_lanes_and_dashes():
    from repro.metrics import report

    bins = 64
    met = streaming.SketchMetric(bins=bins)
    rng = np.random.default_rng(1)
    pos = np.zeros((4, bins), np.float32)
    neg = np.zeros((4, bins), np.float32)
    # lane 0: separable (high AUC); lane 1: overlapping (low AUC);
    # lane 2: positives only (AUC undefined); lane 3: empty
    pos[0, 48:] = 10
    neg[0, :16] = 10
    pos[1, :] = rng.random(bins).astype(np.float32)
    neg[1, :] = rng.random(bins).astype(np.float32)
    pos[2, 10] = 5
    line = report.worker_skew_line("train", 7, met,
                                   {"pos": pos, "neg": neg}, -8.0, 8.0)
    cells = line.split("[")[-1].split("]")[0].split()
    assert len(cells) == 4
    assert cells[2] == "-" and cells[3] == "-"
    assert float(cells[0]) > 0.9 and 0.0 <= float(cells[1]) <= 1.0
    assert "spread=" in line
    skews = streaming.worker_sketches({"pos": pos, "neg": neg}, -8.0, 8.0)
    assert len(skews) == 4 and skews[0].count == 320
    assert skews[3].count == 0


def test_training_sk_loc_holds_each_workers_own_stream():
    """``state["sk_loc"]`` lane k must hold EXACTLY the histogram of worker
    k's own local scores — the per-shard skew readout the window collective
    never touches — while ``sk_acc`` holds the merged stream.  Replayed
    step by step over two windows."""
    mcfg, ccfg, st0, wb = _window_case(K=4, bins=32)
    oracles = [streaming.empty_sketch(ccfg.stream_bins, *ccfg.stream_range)
               for _ in range(4)]
    state = st0
    for _w in range(2):
        replay = state
        for i in range(wb["labels"].shape[0]):
            batch = {k: v[i] for k, v in wb.items()}
            _, _, hs = coda.grad_step_scores(mcfg, ccfg, replay, batch)
            for k in range(4):
                oracles[k] = streaming.update(
                    oracles[k], np.asarray(hs[k]),
                    np.asarray(batch["labels"][k]))
            replay, _ = coda.local_step(mcfg, ccfg, replay, batch,
                                        jnp.float32(0.1))
        state, _ = coda.window_step(mcfg, ccfg, state, wb, jnp.float32(0.1))
    lanes = streaming.worker_sketches(state["sk_loc"], *ccfg.stream_range)
    for k, (got, want) in enumerate(zip(lanes, oracles)):
        assert np.array_equal(got.pos, want.pos), k
        assert np.array_equal(got.neg, want.neg), k
    # the merged accumulator is exactly the sum of the per-worker lanes
    acc = streaming.sketch_from_rows(state["sk_acc"], *ccfg.stream_range)
    assert np.array_equal(acc.pos, sum(o.pos for o in oracles))
    assert np.array_equal(acc.neg, sum(o.neg for o in oracles))
    # ...and sk_loc adds ZERO wire bytes: the payload accounting only ever
    # counts the sk_new deltas
    assert coda.streaming_payload_bytes(state) == 2 * ccfg.stream_bins * 4
