"""Crash-recovery regression tests for the windowed-training checkpoints.

The contract (core/coda.fit + checkpoint/): a run killed mid-flight resumes
from the latest window-boundary checkpoint and finishes BITWISE-identical
to the uninterrupted run — fp32 state, PRNG key, loop counters, loss
history, and comm accounting all round-trip exactly.  The fault schedule is
a pure function of (fault_seed, global window index), so the same holds
under fault injection: the resumed run replays the exact dropout/straggler
vectors the dead run would have seen.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import mlp_config
from repro.core import coda, schedules

MCFG = mlp_config(n_features=8, d=16)
K, I, B, F = 4, 2, 4, 8
SCHED = schedules.ScheduleConfig(n_workers=K, eta0=0.3, T0=8, I0=I)
N_STAGES = 2  # practical mode triples T stagewise: 4 + 12 = 16 windows


def _sample_window(key, n_steps):
    kf, kl = jax.random.split(key)
    y = (jax.random.uniform(kl, (n_steps, K, B)) < 0.6).astype(jnp.float32)
    x = jax.random.normal(kf, (n_steps, K, B, F)) \
        + 0.3 * (y[..., None] * 2 - 1)
    return {"features": x, "labels": y}


def _sample_alpha(key, m):
    kf, kl = jax.random.split(key)
    y = (jax.random.uniform(kl, (K, m)) < 0.6).astype(jnp.float32)
    x = jax.random.normal(kf, (K, m, F)) + 0.3 * (y[..., None] * 2 - 1)
    return {"features": x, "labels": y}


class _Crash(RuntimeError):
    pass


def _crashing_sampler(n_calls: int):
    """A sample_window that dies on its (n_calls+1)-th draw — the window
    loop never reaches that window, exactly like a mid-run worker death."""
    seen = {"n": 0}

    def sample(key, n_steps):
        if seen["n"] >= n_calls:
            raise _Crash(f"simulated crash at window draw {seen['n']}")
        seen["n"] += 1
        return _sample_window(key, n_steps)

    return sample


def _fit(ccfg, **kw):
    return coda.fit(jax.random.PRNGKey(0), MCFG, ccfg, SCHED, N_STAGES,
                    _sample_window, _sample_alpha, **kw)


def _assert_identical(a: coda.FitResult, b: coda.FitResult):
    for pa, pb in zip(jax.tree_util.tree_leaves(a.state),
                      jax.tree_util.tree_leaves(b.state)):
        assert jnp.array_equal(pa, pb), "state leaf differs after resume"
    assert a.history == b.history
    assert a.comm_rounds == b.comm_rounds
    assert a.iterations == b.iterations
    assert a.exposed_bytes == b.exposed_bytes
    assert a.overlapped_bytes == b.overlapped_bytes


@pytest.mark.parametrize("faulted", [False, True],
                         ids=["clean", "fault-injected"])
def test_crash_resume_is_bitwise_identical(tmp_path, faulted):
    kw = dict(participation=0.7, straggler_prob=0.2, max_staleness=1,
              fault_seed=11) if faulted else {}
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.6, **kw)
    want = _fit(ccfg)

    d = str(tmp_path / "run")
    with pytest.raises(_Crash):
        coda.fit(jax.random.PRNGKey(0), MCFG, ccfg, SCHED, N_STAGES,
                 _crashing_sampler(5), _sample_alpha,
                 ckpt_dir=d, ckpt_every=2)
    # died after 5 window draws -> checkpoints at gw = 2 and 4 exist
    assert ckpt.latest_step(d) == 4
    meta = ckpt.load_metadata(d, 4)
    assert meta["gw"] == 4 and meta["rounds"] == 4

    got = _fit(ccfg, ckpt_dir=d, ckpt_every=2, resume=True)
    _assert_identical(want, got)
    # the resumed run kept checkpointing past the crash point
    assert ckpt.latest_step(d) == 16


def test_resume_without_checkpoint_starts_fresh(tmp_path):
    """resume=True against an empty directory is a cold start, not an
    error — the launcher can always pass --resume."""
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.6)
    want = _fit(ccfg)
    got = _fit(ccfg, ckpt_dir=str(tmp_path / "empty"), ckpt_every=4,
               resume=True)
    _assert_identical(want, got)


def test_checkpoint_cadence_and_metadata_roundtrip(tmp_path):
    """Checkpoints land only at window boundaries on the ckpt_every grid,
    and the metadata carries everything fit() needs to resume."""
    d = str(tmp_path / "run")
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.6)
    _fit(ccfg, ckpt_dir=d, ckpt_every=2)
    assert ckpt.latest_step(d) == 16
    for step in range(2, 17, 2):
        meta = ckpt.load_metadata(d, step)
        assert meta["gw"] == step
        for k in ("stage", "w", "rounds", "iters", "exposed",
                  "overlapped", "history"):
            assert k in meta, k
