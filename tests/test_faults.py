"""Fault-injection harness tests (core/faults.py + the masked window path).

Covers the FaultPlan determinism contract (seed replay, random access),
the schedule semantics (dropout/straggle/crash vectors, the never-all-absent
guard, staleness bounds), the CoDAConfig fault-knob validation, and the
masked window math on the vmap oracle:

  * the masked merge IS the exact weighted participant mean (bitwise
    against the hand-computed prescale-sum-divide);
  * CODASCA variate invariants at p = 0.5: ``cg`` equals the exact
    participant mean of the fresh variates, absent workers keep their old
    ``c_k``;
  * mid-straggle workers (resync 0) keep their own iterate;
  * all-ones fault vectors match the unmasked path to fp32 tolerance, and
    p = 1.0 IS the unmasked path (``faults_enabled`` gates at config);
  * composite liveness (hypothesis): dirichlet partitions + participation
    masks never leave a window without participants or a participant
    without data, and a window that sees no positives takes the guarded
    finite path, not NaN.

The masked shard_map equivalence + compiled-HLO payload contracts live in
tests/test_masked_window.py (they need forced host devices).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import mlp_config
from repro.core import coda, faults

MCFG = mlp_config(n_features=8, d=16)
K, I, B = 4, 2, 4


def _wb(key, labels=None):
    kf, kl = jax.random.split(key)
    y = labels if labels is not None else (
        jax.random.uniform(kl, (I, K, B)) < 0.5).astype(jnp.float32)
    x = jax.random.normal(kf, (I, K, B, 8)) + 0.3 * (y[..., None] * 2 - 1)
    return {"features": x, "labels": y}


# --------------------------------------------------------------------------
# FaultPlan: determinism + schedule semantics
# --------------------------------------------------------------------------
def test_plan_replays_from_seed():
    kw = dict(n_workers=6, seed=3, dropout=0.4, straggle=0.2,
              straggle_windows=2, max_staleness=2)
    a, b = faults.FaultPlan(**kw), faults.FaultPlan(**kw)
    # b is driven out of order: random access must agree with sequential
    for w in [5, 0, 11, 3, 7]:
        u2, r2 = b.window(w)
        u1, r1 = a.window(w)
        assert np.array_equal(u1, u2) and np.array_equal(r1, r2), w
        assert u1.dtype == np.float32 and r1.dtype == np.float32
    # a different seed diverges somewhere in the first dozen windows
    c = faults.FaultPlan(**{**kw, "seed": 4})
    assert any(not np.array_equal(a.window(w)[0], c.window(w)[0])
               for w in range(12))


def test_plan_vectors_are_copies():
    plan = faults.FaultPlan(n_workers=4, dropout=0.5)
    u, _ = plan.window(0)
    u[:] = -1.0
    u2, _ = plan.window(0)
    assert float(u2.min()) >= 0.0


def test_plan_never_all_absent():
    # dropout just under the validation bound: the guard must re-admit one
    # dropped worker whenever the draw empties the window
    plan = faults.FaultPlan(n_workers=4, seed=0, dropout=0.99)
    for w in range(50):
        u, r = plan.window(w)
        assert u.sum() > 0.0, w
        assert np.all(r == 1.0), w  # pure dropout: everyone resyncs


def test_plan_crash_semantics():
    plan = faults.FaultPlan(n_workers=3, crashes=((0, 2), (2, 4)))
    for w in range(8):
        u, r = plan.window(w)
        if w >= 2:
            assert u[0] == 0.0 and r[0] == 1.0, w  # dead: tracks merged state
        if w >= 4:
            assert u[2] == 0.0 and r[2] == 1.0, w
        assert u[1] == 1.0  # no other faults configured
    # every worker crashed: nothing left to train — loud, not a hang
    dead = faults.FaultPlan(n_workers=2, crashes=((0, 0), (1, 3)))
    for w in range(3):
        dead.window(w)
    with pytest.raises(RuntimeError, match="crashed"):
        dead.window(3)


def test_plan_crash_entry_validation():
    with pytest.raises(ValueError):
        faults.FaultPlan(n_workers=2, crashes=((5, 0),))
    with pytest.raises(ValueError):
        faults.FaultPlan(n_workers=2, crashes=((0, -1),))


def _episode_invariants(plan, d, max_staleness, discount, n=60):
    """Scan the schedule and check every straggle episode's shape: at most
    ``d`` consecutive (u=0, r=0) windows; an uninterrupted episode of
    exactly ``d`` resolves next window to the discounted merge (d <=
    max_staleness) or the drop+resync (u=0, r=1) otherwise."""
    KK = plan.n_workers
    wins = [plan.window(w) for w in range(n)]
    allowed = {0.0, 1.0, np.float32(discount) ** d}
    run = np.zeros(KK, int)
    saw_arrival = False
    for w, (u, r) in enumerate(wins):
        for k in range(KK):
            assert float(u[k]) in allowed, (w, k, u[k])
            assert r[k] in (0.0, 1.0)
            if r[k] == 0.0:
                assert u[k] == 0.0, (w, k)  # keep-own-state only when absent
                run[k] += 1
                assert run[k] <= d, (w, k)  # bounded in-flight time
            else:
                if run[k] == d:             # uninterrupted episode resolved
                    want = np.float32(discount) ** d \
                        if d <= max_staleness else 0.0
                    assert float(u[k]) == float(want), (w, k, u[k])
                    saw_arrival = True
                run[k] = 0
    assert saw_arrival, "schedule never exercised a straggler arrival"


def test_plan_straggler_merges_within_staleness_bound():
    _episode_invariants(
        faults.FaultPlan(n_workers=4, seed=1, straggle=0.5,
                         straggle_windows=2, max_staleness=2),
        d=2, max_staleness=2, discount=0.5)


def test_plan_straggler_dropped_beyond_staleness_bound():
    plan = faults.FaultPlan(n_workers=4, seed=1, straggle=0.5,
                            straggle_windows=2, max_staleness=1)
    _episode_invariants(plan, d=2, max_staleness=1, discount=0.5)
    # no fractional weights anywhere: too-stale deltas never merge
    assert all(set(np.unique(plan.window(w)[0])) <= {0.0, 1.0}
               for w in range(60))


def test_plan_participants_mask():
    plan = faults.FaultPlan(n_workers=4, seed=1, straggle=0.5,
                            straggle_windows=2, max_staleness=2)
    for w in range(20):
        u, _ = plan.window(w)
        m = plan.participants(w)
        assert np.array_equal(m, (u > 0).astype(np.float32))


def test_plan_from_config_maps_knobs():
    ccfg = coda.CoDAConfig(n_workers=5, participation=0.8,
                           straggler_prob=0.1, straggler_windows=3,
                           max_staleness=2, staleness_discount=0.25,
                           fault_seed=9, crashes=((1, 4),))
    plan = faults.FaultPlan.from_config(ccfg)
    assert plan.n_workers == 5 and plan.seed == 9
    assert plan.dropout == pytest.approx(0.2)
    assert plan.straggle == 0.1 and plan.straggle_windows == 3
    assert plan.max_staleness == 2 and plan.staleness_discount == 0.25
    assert plan.crashes == ((1, 4),)


# --------------------------------------------------------------------------
# CoDAConfig fault knobs
# --------------------------------------------------------------------------
def test_config_fault_knob_validation():
    for bad in (dict(participation=0.0), dict(participation=1.5),
                dict(straggler_prob=1.0), dict(straggler_windows=0),
                dict(max_staleness=-1), dict(staleness_discount=0.0)):
        with pytest.raises(ValueError):
            coda.CoDAConfig(n_workers=2, **bad)


def test_config_faults_enabled_gate():
    assert not coda.CoDAConfig(n_workers=2).faults_enabled
    # staleness/discount knobs alone do NOT enable faults (p = 1.0 stays
    # bit-for-bit the classical path)
    assert not coda.CoDAConfig(n_workers=2, max_staleness=3).faults_enabled
    assert coda.CoDAConfig(n_workers=2, participation=0.5).faults_enabled
    assert coda.CoDAConfig(n_workers=2, straggler_prob=0.1).faults_enabled
    assert coda.CoDAConfig(n_workers=2, crashes=((0, 1),)).faults_enabled


def test_config_rejects_server_momentum_with_faults():
    with pytest.raises(ValueError, match="server momentum"):
        coda.CoDAConfig(n_workers=2, participation=0.5, server_momentum=0.9)
    # either alone is fine
    coda.CoDAConfig(n_workers=2, server_momentum=0.9)
    coda.CoDAConfig(n_workers=2, participation=0.5)


def test_executor_fault_arg_contract():
    key = jax.random.PRNGKey(0)
    wb = _wb(key)
    fl = {"weights": jnp.ones((K,), jnp.float32),
          "resync": jnp.ones((K,), jnp.float32)}
    cfg_on = coda.CoDAConfig(n_workers=K, participation=0.5)
    cfg_off = coda.CoDAConfig(n_workers=K)
    on = coda.make_executor(MCFG, cfg_on, "vmap", donate=False)
    off = coda.make_executor(MCFG, cfg_off, "vmap", donate=False)
    st_on = on.place(coda.init_state(key, MCFG, cfg_on))
    with pytest.raises(ValueError, match="fault"):
        on.window_step(st_on, wb, 0.1)           # enabled but no vectors
    st_off = off.place(coda.init_state(key, MCFG, cfg_off))
    with pytest.raises(ValueError, match="disabled"):
        off.window_step(st_off, wb, 0.1, faults=fl)  # vectors but disabled


# --------------------------------------------------------------------------
# masked window math on the vmap oracle
# --------------------------------------------------------------------------
def _masked_case(algorithm, u, r, key=0, participation=0.6):
    ccfg = coda.CoDAConfig(n_workers=K, algorithm=algorithm,
                           participation=participation)
    kk = jax.random.PRNGKey(key)
    st0 = coda.init_state(kk, MCFG, ccfg)
    wb = _wb(jax.random.PRNGKey(key + 1))
    fl = {"weights": jnp.asarray(u, jnp.float32),
          "resync": jnp.asarray(r, jnp.float32)}
    exe = coda.make_executor(MCFG, ccfg, "vmap", donate=False)
    return ccfg, exe, st0, wb, fl


def test_masked_merge_is_exact_weighted_participant_mean():
    u = np.array([1.0, 0.0, 0.5, 0.0], np.float32)
    r = np.ones(K, np.float32)
    ccfg, exe, st0, wb, fl = _masked_case("coda", u, r)
    merged, _ = exe.window_step(st0, wb, jnp.float32(0.3), faults=fl)
    # the same local steps without the collective give the pre-merge rows
    local, _ = coda.window_step(MCFG, ccfg, st0, wb, jnp.float32(0.3),
                                communicate=False)
    W = u.sum()
    for name in ("params", "duals"):
        for got, loc in zip(jax.tree_util.tree_leaves(merged[name]),
                            jax.tree_util.tree_leaves(local[name])):
            rows = loc.astype(jnp.float32).reshape(K, -1)
            want = (rows * u[:, None]).sum(0) / W
            # resync = 1 everywhere: every worker adopts the merged row
            for k in range(K):
                err = float(jnp.max(jnp.abs(
                    got.astype(jnp.float32).reshape(K, -1)[k] - want)))
                assert err < 1e-6, (name, k, err)


def test_masked_straggler_keeps_own_iterate():
    u = np.array([1.0, 1.0, 0.0, 1.0], np.float32)
    r = np.array([1.0, 1.0, 0.0, 1.0], np.float32)   # worker 2 mid-straggle
    ccfg, exe, st0, wb, fl = _masked_case("coda", u, r)
    merged, _ = exe.window_step(st0, wb, jnp.float32(0.3), faults=fl)
    local, _ = coda.window_step(MCFG, ccfg, st0, wb, jnp.float32(0.3),
                                communicate=False)
    for name in ("params", "duals"):
        for got, loc in zip(jax.tree_util.tree_leaves(merged[name]),
                            jax.tree_util.tree_leaves(local[name])):
            assert jnp.array_equal(got[2], loc[2]), name   # kept its own
            assert not jnp.array_equal(got[0], loc[0])     # merged


def test_codasca_participant_mean_invariant_at_half_participation():
    u = np.array([1.0, 0.0, 1.0, 0.0], np.float32)
    r = np.ones(K, np.float32)
    _, exe, st0, wb, fl = _masked_case("codasca", u, r, participation=0.5)
    st2, _ = exe.window_step(st0, wb, jnp.float32(0.3), faults=fl)
    for field in ("params", "duals"):
        cg = jax.tree_util.tree_leaves(st2[f"cg_{field}"])
        cv = jax.tree_util.tree_leaves(st2[f"cv_{field}"])
        for g, v in zip(cg, cv):
            # cg == EXACT mean of the participants' fresh variates
            part_mean = (v[0].astype(jnp.float32)
                         + v[2].astype(jnp.float32)) / 2.0
            assert float(jnp.max(jnp.abs(
                g[0].astype(jnp.float32) - part_mean))) == 0.0
            # cg replicated across the worker axis
            for k in range(1, K):
                assert jnp.array_equal(g[k], g[0])
            # absent workers keep their old (zero-initialized) variates
            assert float(jnp.max(jnp.abs(v[1]))) == 0.0
            assert float(jnp.max(jnp.abs(v[3]))) == 0.0


def test_all_ones_fault_vectors_match_unmasked_path():
    for algorithm in ("coda", "codasca"):
        u = np.ones(K, np.float32)
        r = np.ones(K, np.float32)
        ccfg, exe, st0, wb, fl = _masked_case(algorithm, u, r)
        masked, _ = exe.window_step(st0, wb, jnp.float32(0.3), faults=fl)
        plain_cfg = coda.CoDAConfig(n_workers=K, algorithm=algorithm)
        plain_exe = coda.make_executor(MCFG, plain_cfg, "vmap", donate=False)
        plain, _ = plain_exe.window_step(st0, wb, jnp.float32(0.3))
        for (p, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(masked)[0],
                jax.tree_util.tree_flatten_with_path(plain)[0]):
            err = float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                        - b.astype(jnp.float32))))
            assert err < 1e-6, (algorithm, jax.tree_util.keystr(p), err)


def test_full_participation_is_bitwise_the_existing_path():
    """p = 1.0 with no other fault knobs compiles and runs the EXACT old
    window program: ``faults_enabled`` is False, so nothing masked is even
    traced — fit results are bitwise identical to the default config."""
    from repro.core import schedules
    base = coda.CoDAConfig(n_workers=K, p_pos=0.6)
    p1 = coda.CoDAConfig(n_workers=K, p_pos=0.6, participation=1.0,
                         max_staleness=2, staleness_discount=0.25)
    assert not p1.faults_enabled
    sched = schedules.ScheduleConfig(n_workers=K, eta0=0.4, T0=8, I0=2)
    key = jax.random.PRNGKey(0)

    def sample_window(k, i):
        return _wb_of(k, i)

    def _wb_of(k, i):
        kf, kl = jax.random.split(k)
        y = (jax.random.uniform(kl, (i, K, B)) < 0.6).astype(jnp.float32)
        return {"features": jax.random.normal(kf, (i, K, B, 8)), "labels": y}

    def sample_alpha(k, m):
        kf, kl = jax.random.split(k)
        y = (jax.random.uniform(kl, (K, m)) < 0.6).astype(jnp.float32)
        return {"features": jax.random.normal(kf, (K, m, 8)), "labels": y}

    r0 = coda.fit(key, MCFG, base, sched, 2, sample_window, sample_alpha)
    r1 = coda.fit(key, MCFG, p1, sched, 2, sample_window, sample_alpha)
    assert r0.comm_rounds == r1.comm_rounds
    for a, b in zip(jax.tree_util.tree_leaves(r0.state),
                    jax.tree_util.tree_leaves(r1.state)):
        assert jnp.array_equal(a, b)


# --------------------------------------------------------------------------
# composite liveness: dirichlet shards × participation masks (hypothesis)
# --------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       alpha=st.floats(min_value=0.05, max_value=5.0),
       n_workers=st.integers(min_value=2, max_value=8),
       dropout=st.floats(min_value=0.0, max_value=0.9),
       straggle=st.floats(min_value=0.0, max_value=0.5))
def test_partition_plus_masks_never_starve_a_window(seed, alpha, n_workers,
                                                    dropout, straggle):
    """Every window has >= 1 participant (the plan guard) and every
    participant's dirichlet shard is non-empty (the partition top-up), so
    the merged window always has data; whenever any participating shard
    holds positives the merged window keeps the positive class."""
    from repro.data.synthetic import dirichlet_partition
    rng = np.random.RandomState(seed)
    labels = (rng.uniform(size=256) < 0.3).astype(np.float32)
    shards = dirichlet_partition(rng, labels, n_workers, alpha)
    # exact tiling + no starved shard (the precondition for sampling)
    assert sorted(np.concatenate(shards).tolist()) == list(range(256))
    assert all(len(s) > 0 for s in shards)
    plan = faults.FaultPlan(n_workers=n_workers, seed=seed, dropout=dropout,
                            straggle=straggle, straggle_windows=1,
                            max_staleness=1)
    shard_has_pos = np.array([labels[s].sum() > 0 for s in shards])
    for w in range(25):
        m = plan.participants(w)
        assert m.sum() >= 1.0, w
        merged_pool = np.concatenate([shards[k] for k in range(n_workers)
                                      if m[k] > 0])
        assert merged_pool.size > 0, w
        if shard_has_pos[m > 0].any():
            assert labels[merged_pool].sum() > 0, w


def test_no_positive_window_takes_guard_path_not_nan():
    """A window whose batches contain NO positives anywhere must flow
    through the masked merge to a finite state (the objective's eps-guarded
    means), never NaN/Inf."""
    u = np.array([1.0, 0.0, 1.0, 1.0], np.float32)
    r = np.ones(K, np.float32)
    for algorithm in ("coda", "codasca"):
        ccfg, exe, st0, _, fl = _masked_case(algorithm, u, r)
        wb = _wb(jax.random.PRNGKey(5),
                 labels=jnp.zeros((I, K, B), jnp.float32))
        st2, losses = exe.window_step(st0, wb, jnp.float32(0.3), faults=fl)
        for leaf in jax.tree_util.tree_leaves(st2):
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), \
                algorithm
        assert bool(jnp.all(jnp.isfinite(losses))), algorithm
