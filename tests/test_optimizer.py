"""Optimizer-seam tests (core/optimizer.py + kernels/opt_update.py).

The load-bearing claims, each pinned here:

  * ``optimizer="sgd"`` is bit-for-bit the pre-seam path: no ``"opt"``
    entry in the state, the window payload accounting is unchanged for
    EVERY optimizer, and the fused kernel with ``coef=0`` reproduces the
    plain prox update bitwise (fp32, int8-compressed, and fault-masked
    windows alike);
  * optimizer state is strictly local: the window averaging (plain, int8,
    and masked/faulted) never touches ``state["opt"]`` — the subtree is
    bitwise identical to a ``communicate=False`` run — while the params it
    synced are replicated across workers;
  * the sharded executor matches the vmap oracle for the stateful
    optimizers (subprocess, 8 forced host devices, fp32 tight / bf16 at
    stochastic-rounding scale);
  * bf16 accumulator storage stays within a bounded drift of the fp32 run;
  * checkpoint resume with optimizer state is bitwise identical to the
    uninterrupted run (the stochastic-rounding hash is deterministic in
    (value, step-counter seed) — no PRNG key threads the local steps);
  * the audit names an exact-size window-payload excess as an optimizer
    wire leak (red-team: deliberately under-claim the expected bytes);
  * the Pallas kernel (interpret mode off-TPU) matches the jnp oracle at
    fp32 noise scale (the two are separately compiled programs, so XLA's
    FMA contraction may differ per op of the prox chain — last-bit
    absolute differences, which cancellation can make large in ULP terms),
    and its launch geometry passes the R5 static checks.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import audit
from repro.configs.base import mlp_config
from repro.core import coda, optimizer, schedules
from repro.kernels import ops as kops
from repro.kernels import opt_update as OK
from repro.kernels import ref as kref

MCFG = mlp_config(n_features=16, d=32)


def _case(K=4, I=3, B=8, seed=0, **kw):
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7, **kw)
    key = jax.random.PRNGKey(seed)
    st0 = coda.init_state(key, MCFG, ccfg)
    ky, kx = jax.random.split(key)
    y = (jax.random.uniform(ky, (I, K, B)) < 0.7).astype(jnp.float32)
    x = jax.random.normal(kx, (I, K, B, 16)) + 0.3 * (y[..., None] * 2 - 1)
    return ccfg, st0, {"features": x, "labels": y}


def _faults(K, weights):
    return {"weights": jnp.asarray(weights, jnp.float32),
            "resync": jnp.zeros((K,), jnp.float32)}


def _tree_equal(a, b):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert len(la) == len(lb)
    for (p, x), (_, y) in zip(la, lb):
        assert jnp.array_equal(x, y), jax.tree_util.keystr(p)


def _tree_close(a, b, tol, label=""):
    for (p, x), (_, y) in zip(jax.tree_util.tree_leaves_with_path(a),
                              jax.tree_util.tree_leaves_with_path(b)):
        err = float(jnp.max(jnp.abs(x.astype(jnp.float32) -
                                    y.astype(jnp.float32))))
        assert err < tol, (label, jax.tree_util.keystr(p), err)


# --------------------------------------------------------------------------
# sgd is bit-for-bit the pre-seam path
# --------------------------------------------------------------------------
def test_sgd_state_has_no_opt_entry_and_payload_is_optimizer_independent():
    """The seam must be invisible under sgd: no ``"opt"`` key (same
    treedef, same checkpoint manifest, same donation layout as before the
    seam existed), and the window-payload accounting must not move for ANY
    optimizer — preconditioning is local, the wire contract is fixed."""
    _, sgd_st, _ = _case()
    assert set(sgd_st) == {"params", "duals", "ref_params", "ref_duals"}
    base = coda.window_payload_bytes(sgd_st)
    assert coda.opt_state_bytes(sgd_st) == 0
    for name in ("momentum", "sm3", "shampoo_blocked"):
        _, st, _ = _case(optimizer=name, shampoo_block=8)
        assert "opt" in st, name
        assert coda.window_payload_bytes(st) == base, name
        assert coda.window_payload_by_dtype(st) == \
            coda.window_payload_by_dtype(sgd_st), name
        assert coda.opt_state_bytes(st) > 0, name


def test_momentum_beta0_fp32_reproduces_sgd_bitwise():
    """β=0 fp32 momentum degenerates to d=g with an identity re-store, so
    the params/duals trajectory must equal sgd's BITWISE — across a plain
    fp32 window, an int8-compressed window, and a fault-masked window.
    This pins the fused kernel's prox arithmetic to the pre-seam path."""
    for kw, faults in [({}, None),
                       ({"avg_compress": "int8"}, None),
                       ({}, _faults(4, [1.0, 0.0, 1.0, 1.0]))]:
        ccfg_s, st_s, wb = _case(**kw)
        ccfg_m, st_m, _ = _case(optimizer="momentum", opt_beta=0.0, **kw)
        out_s, loss_s = coda.window_step(MCFG, ccfg_s, st_s, wb,
                                         jnp.float32(0.1), faults=faults)
        out_m, loss_m = coda.window_step(MCFG, ccfg_m, st_m, wb,
                                         jnp.float32(0.1), faults=faults)
        _tree_equal({k: out_m[k] for k in out_s}, out_s)
        assert jnp.array_equal(loss_s, loss_m)


def test_opt_update_coef0_is_prox_update_bitwise():
    v = jax.random.normal(jax.random.PRNGKey(0), (257,))
    g = jax.random.normal(jax.random.PRNGKey(1), (257,))
    v0 = jax.random.normal(jax.random.PRNGKey(2), (257,))
    m = jnp.zeros((257,), jnp.float32)
    nv, nm = kref.opt_update_ref(v, g, v0, m, 0.1, 0.5, 0.0,
                                 jnp.uint32(7), mode="momentum")
    want = kref.prox_update_ref(v, g, v0, 0.1, 0.5)
    assert jnp.array_equal(nv, want)
    assert jnp.array_equal(nm, g)


# --------------------------------------------------------------------------
# optimizer state is strictly local
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name,kw", [
    ("momentum", {}),
    ("sm3", {}),
    ("shampoo_blocked", {"shampoo_block": 8, "precond_every": 2}),
])
def test_averaging_never_touches_opt_state(name, kw):
    """Every averaging flavor must pass ``state["opt"]`` through untouched:
    the subtree after a communicating window is bitwise the subtree of the
    same window run silent, while the synced params are replicated across
    worker rows and the per-worker accumulators are NOT."""
    for extra, faults in [({}, None),
                          ({"avg_compress": "int8"}, None),
                          ({}, _faults(4, [1.0, 0.0, 1.0, 0.5]))]:
        if name == "shampoo_blocked" and extra:
            continue          # one compress case is enough; keep it fast
        ccfg, st0, wb = _case(optimizer=name, **kw, **extra)
        synced, _ = coda.window_step(MCFG, ccfg, st0, wb, jnp.float32(0.1),
                                     faults=faults)
        silent, _ = coda.window_step(MCFG, ccfg, st0, wb, jnp.float32(0.1),
                                     communicate=False)
        _tree_equal(synced["opt"], silent["opt"])
        assert int(synced["opt"]["t"][0]) == wb["labels"].shape[0]
        for leaf in jax.tree_util.tree_leaves(synced["params"]):
            assert np.array_equal(
                np.asarray(leaf),
                np.broadcast_to(np.asarray(leaf[0]), leaf.shape)) \
                or faults is not None
        # per-worker accumulators differ across workers (different local
        # streams) — averaging them would have erased exactly this
        bufs = [l for l in jax.tree_util.tree_leaves(synced["opt"]["leaves"])
                if l.ndim > 1]
        assert any(
            not np.array_equal(np.asarray(l[0]), np.asarray(l[1]))
            for l in bufs), name


def test_resync_adopts_merged_params_but_keeps_local_opt_state():
    """A worker past max_staleness re-syncs: its params jump to the merged
    iterate, its optimizer state stays its own (bitwise the silent run's)."""
    K = 4
    ccfg, st0, wb = _case(K=K, optimizer="momentum")
    faults = {"weights": jnp.asarray([1.0, 1.0, 1.0, 0.0], jnp.float32),
              "resync": jnp.asarray([0.0, 0.0, 0.0, 1.0], jnp.float32)}
    synced, _ = coda.window_step(MCFG, ccfg, st0, wb, jnp.float32(0.1),
                                 faults=faults)
    silent, _ = coda.window_step(MCFG, ccfg, st0, wb, jnp.float32(0.1),
                                 communicate=False)
    _tree_equal(synced["opt"], silent["opt"])
    for leaf in jax.tree_util.tree_leaves(synced["params"]):
        # the resynced worker 3 holds the same merged replica as worker 0
        assert np.array_equal(np.asarray(leaf[3]), np.asarray(leaf[0]))


# --------------------------------------------------------------------------
# bf16 accumulator drift
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["momentum", "sm3"])
def test_bf16_opt_state_drift_is_bounded(name):
    """Stochastically-rounded bf16 accumulators must track the fp32 run:
    after several windows the params drift stays at rounding scale, far
    from the divergence/no-learning failure modes."""
    ccfg32, st32, wb = _case(optimizer=name)
    ccfg16, st16, _ = _case(optimizer=name, opt_dtype=jnp.bfloat16)
    for _ in range(4):
        st32, _ = coda.window_step(MCFG, ccfg32, st32, wb, jnp.float32(0.1))
        st16, _ = coda.window_step(MCFG, ccfg16, st16, wb, jnp.float32(0.1))
    _tree_close(st16["params"], st32["params"], 2e-2, name)
    assert coda.opt_state_bytes(st16) < coda.opt_state_bytes(st32)


def test_bf16_halves_opt_state_bytes_and_abstract_matches_concrete():
    for name in ("momentum", "sm3", "shampoo_blocked"):
        sizes = {}
        for dt in (jnp.float32, jnp.bfloat16):
            ccfg, st, _ = _case(optimizer=name, opt_dtype=dt,
                                shampoo_block=8)
            sizes[dt] = coda.opt_state_bytes(st)
            assert optimizer.abstract_state_bytes(
                ccfg, jax.eval_shape(lambda s: s, st)["params"]) == sizes[dt]
        ratio = sizes[jnp.float32] / sizes[jnp.bfloat16]
        assert ratio >= 1.9, (name, ratio)   # the ISSUE's memory target


# --------------------------------------------------------------------------
# registry / config surface
# --------------------------------------------------------------------------
def test_registry_names_and_config_validation():
    assert set(optimizer.names()) == {"sgd", "momentum", "sm3",
                                      "shampoo_blocked"}
    with pytest.raises(ValueError, match="unknown optimizer"):
        coda.CoDAConfig(n_workers=2, optimizer="adam")
    with pytest.raises(ValueError, match="opt_dtype"):
        coda.CoDAConfig(n_workers=2, optimizer="sm3", opt_dtype=jnp.float16)
    with pytest.raises(ValueError, match="shampoo_block"):
        coda.CoDAConfig(n_workers=2, shampoo_block=0)
    with pytest.raises(ValueError, match="precond_every"):
        coda.CoDAConfig(n_workers=2, precond_every=0)
    with pytest.raises(ValueError, match="opt_beta"):
        coda.CoDAConfig(n_workers=2, opt_beta=1.0)


# --------------------------------------------------------------------------
# checkpoint resume with optimizer state
# --------------------------------------------------------------------------
class _Crash(RuntimeError):
    pass


def test_checkpoint_resume_with_opt_state_is_bitwise(tmp_path):
    """Crash-resume with bf16 sm3 state must be bitwise identical to the
    uninterrupted run: the state dict now carries ``"opt"`` (mixed int32 /
    bf16 leaves) and the stochastic-rounding seeds replay from the
    checkpointed step counter."""
    K, I, B, F = 4, 2, 4, 8
    mcfg = mlp_config(n_features=F, d=16)
    sched = schedules.ScheduleConfig(n_workers=K, eta0=0.3, T0=8, I0=I)
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.6, optimizer="sm3",
                           opt_dtype=jnp.bfloat16)

    def sample_window(key, n_steps):
        kf, kl = jax.random.split(key)
        y = (jax.random.uniform(kl, (n_steps, K, B)) < 0.6) \
            .astype(jnp.float32)
        x = jax.random.normal(kf, (n_steps, K, B, F)) \
            + 0.3 * (y[..., None] * 2 - 1)
        return {"features": x, "labels": y}

    def sample_alpha(key, m):
        kf, kl = jax.random.split(key)
        y = (jax.random.uniform(kl, (K, m)) < 0.6).astype(jnp.float32)
        x = jax.random.normal(kf, (K, m, F)) + 0.3 * (y[..., None] * 2 - 1)
        return {"features": x, "labels": y}

    def crashing(n_calls):
        seen = {"n": 0}

        def sample(key, n_steps):
            if seen["n"] >= n_calls:
                raise _Crash("boom")
            seen["n"] += 1
            return sample_window(key, n_steps)

        return sample

    want = coda.fit(jax.random.PRNGKey(0), mcfg, ccfg, sched, 2,
                    sample_window, sample_alpha)
    assert "opt" in want.state
    d = str(tmp_path / "run")
    with pytest.raises(_Crash):
        coda.fit(jax.random.PRNGKey(0), mcfg, ccfg, sched, 2,
                 crashing(5), sample_alpha, ckpt_dir=d, ckpt_every=2)
    got = coda.fit(jax.random.PRNGKey(0), mcfg, ccfg, sched, 2,
                   sample_window, sample_alpha, ckpt_dir=d, ckpt_every=2,
                   resume=True)
    _tree_equal(got.state, want.state)
    assert got.history == want.history
    assert got.comm_rounds == want.comm_rounds


# --------------------------------------------------------------------------
# fused kernel: interpret ≡ oracle, R5 geometry
# --------------------------------------------------------------------------
@pytest.mark.parametrize("mode,buf_dtype", [
    ("momentum", jnp.float32),
    ("momentum", jnp.bfloat16),
    ("precond", jnp.float32),
])
def test_opt_update_kernel_interpret_matches_ref(mode, buf_dtype):
    """The Pallas kernel (interpret mode off-TPU) and the jnp oracle share
    the stochastic-rounding hash as the same integer ops, but the two are
    separately compiled programs: XLA is free to contract the prox chain's
    mul+adds into FMAs in one and not the other, and where ``coef·m + g``
    cancels toward zero that last-bit difference is large in relative
    terms (and can flip a stochastic-rounding decision by one bf16 step).
    So the pin is the repo's kernel-vs-oracle idiom — allclose at fp32
    noise scale for the prox result, bf16 rounding scale for a rounded
    buffer — which still catches every real bug class here (wrong seed
    lane, fp32-vs-bf16 math, off-by-one tiles produce order-of-magnitude
    diffs).  Exercised at a length that does not divide the block size."""
    for n in (64, 1000):
        ks = jax.random.split(jax.random.PRNGKey(n), 4)
        v = jax.random.normal(ks[0], (n,))
        g = jax.random.normal(ks[1], (n,))
        v0 = jax.random.normal(ks[2], (n,))
        if mode == "momentum":
            buf = (jax.random.normal(ks[3], (n,))).astype(buf_dtype)
        else:
            buf = jnp.abs(jax.random.normal(ks[3], (n,)))  # fp32 cover ≥ 0
        args = (v, g, v0, buf, 0.1, 0.5,
                0.9 if mode == "momentum" else 1e-6, jnp.uint32(12345))
        nv_k, nb_k = kops.opt_update(*args, mode=mode, impl="pallas")
        nv_r, nb_r = kops.opt_update(*args, mode=mode, impl="ref")
        np.testing.assert_allclose(np.asarray(nv_k), np.asarray(nv_r),
                                   rtol=1e-6, atol=1e-6, err_msg=f"{mode} v n={n}")
        assert nb_k.dtype == buf.dtype
        tol = 1e-2 if buf.dtype == jnp.bfloat16 else 1e-6
        np.testing.assert_allclose(np.asarray(nb_k, np.float32),
                                   np.asarray(nb_r, np.float32),
                                   rtol=tol, atol=tol, err_msg=f"{mode} buf n={n}")


def test_opt_update_launch_geometry_passes_r5():
    """The static launch checks the audit enforces in CI, exercised over
    sub-block, exact-block, and padded sizes."""
    for N in (1, 8, 1000, 4096, 5000):
        g = OK.launch_geometry(N)
        assert g["Np"] >= N and g["Np"] % g["bt"] == 0
        for mode in ("momentum", "precond"):
            launch = audit.PallasLaunch(
                kernel=f"opt_update[{mode}]", grid=g["grid"],
                blocks={"n": (g["Np"], g["bt"])})
            assert audit.launch_problems(launch) == [], (N, mode)


# --------------------------------------------------------------------------
# sharded executor (subprocess: 8 forced host devices)
# --------------------------------------------------------------------------
_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.analysis import audit as A
    from repro.configs.base import mlp_config
    from repro.core import coda
    mcfg = mlp_config(n_features=16, d=32)

    def make_case(K, I, B=8, seed=0, **kw):
        ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7, **kw)
        key = jax.random.PRNGKey(seed)
        st0 = coda.init_state(key, mcfg, ccfg)
        ky, kx = jax.random.split(key)
        y = (jax.random.uniform(ky, (I, K, B)) < 0.7).astype(jnp.float32)
        x = jax.random.normal(kx, (I, K, B, 16)) + 0.3 * (y[..., None] * 2 - 1)
        return ccfg, st0, {"features": x, "labels": y}

    def max_err(a, b):
        return max(float(jnp.max(jnp.abs(x.astype(jnp.float32) -
                                         y.astype(jnp.float32))))
                   for x, y in zip(jax.tree_util.tree_leaves(a),
                                   jax.tree_util.tree_leaves(b)))
""")


def _run_sub(script: str, timeout=900):
    r = subprocess.run([sys.executable, "-c",
                        _PRELUDE + textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "ALL OK" in r.stdout, r.stdout[-2000:]


def test_shard_map_matches_vmap_oracle_for_stateful_optimizers():
    """Two windows of each stateful optimizer through the real 8-device
    shard_map executor vs the vmap oracle.  fp32: tight (the Newton–Schulz
    inverse root is pure matmuls, so both executors trace the same
    program).  bf16: the stochastic-rounding hash sees bitwise-identical
    inputs only until the first ulp-level scheduling difference, so bf16
    buffers may differ by a few ulp of their magnitude — params stay at
    fp32-feedback scale."""
    _run_sub("""
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    K, I = 8, 4
    cases = [
        ("momentum", jnp.float32, {}),
        ("sm3", jnp.float32, {}),
        ("sm3", jnp.bfloat16, {}),
        ("shampoo_blocked", jnp.float32,
         {"shampoo_block": 8, "precond_every": 2}),
        ("shampoo_blocked", jnp.bfloat16,
         {"shampoo_block": 8, "precond_every": 2}),
    ]
    for name, dt, kw in cases:
        label = f"{name}/{jnp.dtype(dt).name}"
        ccfg, st0, wb = make_case(K, I, optimizer=name, opt_dtype=dt, **kw)
        exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                                 donate=False)
        st_s = exe.place(st0)
        st_v = st0
        for w in range(2):
            st_s, _ = exe.window_step(st_s, wb, 0.1)
            st_v, _ = coda.window_step(mcfg, ccfg, st_v, wb,
                                       jnp.float32(0.1))
        fp32 = jnp.dtype(dt) == jnp.dtype(jnp.float32)
        ptol = 1e-4 if fp32 else 1e-2
        pe = max_err(st_s["params"], st_v["params"])
        de = max_err(st_s["duals"], st_v["duals"])
        assert pe < ptol and de < ptol, (label, pe, de)
        assert int(st_s["opt"]["t"][0]) == 2 * I, label
        if fp32:
            oe = max_err(st_s["opt"], st_v["opt"])
            assert oe < 1e-2, (label, oe)
        print("OK", label, pe)
    print("ALL OK")
    """)


def test_window_payload_audit_red_team_names_opt_state_leak():
    """Red-team for the wire contract: the compiled sm3 window must pass
    the byte-exact payload assert at the ACCOUNTED size, and an expectation
    that is short by exactly ``opt_state_bytes`` must fail with the
    diagnosis naming the optimizer leak (that is what the excess would mean
    if the opt tree ever joined the bucket)."""
    _run_sub("""
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    K, I, B = 8, 2, 8
    ccfg, st0, _ = make_case(K, I, optimizer="sm3")
    exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                             donate=False)
    wb = {"features": jax.ShapeDtypeStruct((I, K, B, 16), jnp.float32),
          "labels": jax.ShapeDtypeStruct((I, K, B), jnp.float32)}
    sts = jax.eval_shape(lambda s: s, st0)
    txt = exe.window_fn(sts, wb).lower(
        sts, wb, jax.ShapeDtypeStruct((), jnp.float32)).compile().as_text()

    payload = coda.window_payload_bytes(st0)
    ob = coda.opt_state_bytes(st0)
    assert ob > 0
    # the honest contract holds: sm3's window ships exactly the sgd bytes
    A.assert_window_payload(txt, payload, opt_bytes=ob)
    # red team: under-claim by exactly the optimizer state; the failure
    # must NAME the leak instead of leaving a raw byte delta
    try:
        A.assert_window_payload(txt, payload - ob, opt_bytes=ob)
        raise SystemExit("under-claimed payload must fail")
    except AssertionError as e:
        assert "optimizer state leaked onto the wire" in str(e), str(e)
    # without the hint the same mismatch is a plain byte report
    try:
        A.assert_window_payload(txt, payload - ob)
        raise SystemExit("under-claimed payload must fail")
    except AssertionError as e:
        assert "leaked" not in str(e)
    print("ALL OK")
    """)
