"""Objective-layer tests (the pluggable min-max seam, core/objective.py).

Covers:
  * metric oracles — ``roc_auc`` and ``partial_auc`` pinned against the
    O(n²) pairwise comparison oracles under hypothesis, including all-ties
    and single-class edge batches;
  * THE refactor acceptance pin — the generic dual-tree path
    (``objective="auc"``) reproduced against an inline re-implementation of
    the pre-refactor scalar-field formulas (explicit a/b/α prox + ascent,
    per-field averaging) for CoDA fp32, CoDA int8, and CODASCA, on the vmap
    oracle.  The shard_map executor is pinned to the vmap oracle in
    tests/test_coda_sharded.py / test_codasca.py, and the overlapped ring
    to the blocking path in tests/test_overlap.py, so the legacy pin here
    covers both executors and all averaging variants transitively;
  * pAUC-DRO — gradient correctness by finite differences, the λ floor
    projection, DRO-weight concentration in λ, NaN-free all-positive
    batches (Dirichlet-starved shards), and the sharded path (subprocess,
    8 forced host devices: oracle equivalence + the one-all-reduce payload
    invariant with the 4-field dual tree);
  * server momentum — β = 0 is bit-for-bit the plain path, β > 0 matches
    the manual m ← βm + (x̄ − x₀), x ← x₀ + m recursion over windows, and
    the buffer never enters the wire payload;
  * the BCE objective seam — the loss is logit-space BCE pinned against an
    explicit sigmoid+log oracle with non-vanishing gradients (the old form
    clipped the unbounded score logit into (0, 1) as if it were a
    probability, so gradients vanished exactly outside that range),
    ``baselines.bce_step`` equals the manual formula, and the empty dual
    tree trains through both window paths with zero dual payload.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import mlp_config
from repro.core import baselines, coda, codasca, objective
from repro.kernels import ops as kops
from repro.models import model as M

MCFG = mlp_config(n_features=16, d=32)


def _window(key, I, K, B=8, p=0.7):
    ky, kx = jax.random.split(key)
    y = (jax.random.uniform(ky, (I, K, B)) < p).astype(jnp.float32)
    x = jax.random.normal(kx, (I, K, B, 16)) + 0.3 * (y[..., None] * 2 - 1)
    return {"features": x, "labels": y}


def _max_err(a, b):
    return max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(
            x.astype(jnp.float32) - y.astype(jnp.float32)))), a, b)))


# --------------------------------------------------------------------------
# metric oracles (hypothesis)
# --------------------------------------------------------------------------
_scores = st.lists(st.sampled_from([0.0, 0.1, 0.25, 0.5, 0.5, 0.9, 1.0]),
                   min_size=1, max_size=60)


def _pairwise_auc(sp, sn):
    """The O(n²) oracle: mean over all (pos, neg) pairs of 1[p>n] + ½1[p=n]."""
    if len(sp) == 0 or len(sn) == 0:
        return 0.0  # the documented degenerate-batch convention
    sp, sn = np.asarray(sp, np.float64), np.asarray(sn, np.float64)
    return float(np.mean((sp[:, None] > sn[None, :])
                         + 0.5 * (sp[:, None] == sn[None, :])))


@settings(max_examples=60, deadline=None)
@given(scores=_scores, seed=st.integers(0, 10_000))
def test_roc_auc_matches_pairwise_oracle(scores, seed):
    """The tie-aware rank formula == the O(n²) pairwise count, on heavily
    tied batches — including all-ties and single-class draws (labels may
    come out all-0 or all-1, where both sides return the 0.0 convention)."""
    s = np.asarray(scores, np.float32)
    y = (np.random.RandomState(seed).uniform(size=len(s)) < 0.5).astype(np.float32)
    want = _pairwise_auc(s[y > 0.5], s[y <= 0.5])
    got = float(objective.roc_auc(jnp.asarray(s), jnp.asarray(y)))
    assert abs(got - want) < 1e-5, (got, want, s.tolist(), y.tolist())


def test_roc_auc_all_ties_and_single_class():
    s = jnp.full((8,), 0.5)
    y = jnp.array([1, 0, 1, 0, 1, 0, 1, 0], jnp.float32)
    assert abs(float(objective.roc_auc(s, y)) - 0.5) < 1e-6
    assert float(objective.roc_auc(s, jnp.ones(8))) == 0.0
    assert float(objective.roc_auc(s, jnp.zeros(8))) == 0.0


@settings(max_examples=60, deadline=None)
@given(scores=_scores, seed=st.integers(0, 10_000),
       beta=st.sampled_from([0.1, 0.3, 0.5, 1.0]))
def test_partial_auc_matches_pairwise_oracle(scores, seed, beta):
    """pAUC@FPR≤β == the O(n²) oracle restricted to the top-⌈β·n⁻⌉
    negatives.  Tied negatives at the cutoff are interchangeable (equal
    scores give equal pair outcomes), so the subset choice is immaterial."""
    s = np.asarray(scores, np.float64)
    y = (np.random.RandomState(seed).uniform(size=len(s)) < 0.5).astype(np.float64)
    sp, sn = s[y > 0.5], s[y <= 0.5]
    if len(sp) and len(sn):
        k = max(1, int(np.ceil(beta * len(sn))))
        want = _pairwise_auc(sp, np.sort(sn)[::-1][:k])
    else:
        want = 0.0
    got = objective.partial_auc(s, y, beta)
    assert abs(got - want) < 1e-9, (got, want, beta)


def test_partial_auc_beta_one_is_roc_auc():
    rng = np.random.RandomState(0)
    s = rng.uniform(size=300)
    y = (rng.uniform(size=300) < 0.3).astype(np.float32)
    assert abs(objective.partial_auc(s, y, 1.0)
               - float(objective.roc_auc(jnp.asarray(s), jnp.asarray(y)))) < 1e-5


def test_partial_auc_rewards_head_ranking():
    """pAUC@0.3 is the FPR-head metric: with 30 negatives it ranks the
    positives against the 9 hardest only, so head mistakes (negatives
    scored above the positives) are punished ~(n⁻/k)× harder than the full
    AUC punishes them."""
    y = np.array([1] * 10 + [0] * 30, np.float32)
    good = np.concatenate([np.full(10, 0.8),
                           np.full(3, 0.9), np.full(27, 0.1)])  # 3 negs above
    bad = np.concatenate([np.full(10, 0.8),
                          np.full(9, 0.9), np.full(21, 0.1)])   # 9 negs above
    pa_good = objective.partial_auc(good, y, 0.3)   # beats 6 of top-9
    pa_bad = objective.partial_auc(bad, y, 0.3)     # beats 0 of top-9
    assert abs(pa_good - 6 / 9) < 1e-9 and pa_bad == 0.0
    # the full AUC barely notices the same head damage
    auc_good = float(objective.roc_auc(jnp.asarray(good), jnp.asarray(y)))
    auc_bad = float(objective.roc_auc(jnp.asarray(bad), jnp.asarray(y)))
    assert (auc_good - auc_bad) < (pa_good - pa_bad)


# --------------------------------------------------------------------------
# THE acceptance pin: generic dual trees == the pre-refactor formulas
# --------------------------------------------------------------------------
def _legacy_state(state):
    """New-layout state → the pre-refactor scalar-field layout."""
    d = state["duals"]
    return {"params": state["params"], "a": d["a"], "b": d["b"],
            "alpha": d["alpha"], "ref_params": state["ref_params"],
            "ref_a": state["ref_duals"]["a"], "ref_b": state["ref_duals"]["b"]}


def _legacy_local_step(ccfg, state, batch, eta):
    """The seed repo's hard-coded AUC local step, verbatim formulas."""
    vg = jax.value_and_grad(
        lambda p_, a_, b_, al_, bt_: _legacy_worker_loss(ccfg, p_, a_, b_,
                                                         al_, bt_),
        argnums=(0, 1, 2, 3))
    losses, (gp, ga, gb, galpha) = jax.vmap(vg)(
        state["params"], state["a"], state["b"], state["alpha"], batch)
    new_params = kops.prox_update_tree(state["params"], gp,
                                       state["ref_params"], eta, ccfg.gamma,
                                       impl=ccfg.impl)
    prox = lambda v, g, v0: (ccfg.gamma * (v - eta * g) + eta * v0) / (eta + ccfg.gamma)
    new = dict(state)
    new["params"] = new_params
    new["a"] = prox(state["a"], ga, state["ref_a"])
    new["b"] = prox(state["b"], gb, state["ref_b"])
    new["alpha"] = state["alpha"] + eta * galpha  # dual ascent
    return new, losses, (gp, ga, gb, galpha)


def _legacy_worker_loss(ccfg, params, a, b, alpha, batch):
    inputs = {k: v for k, v in batch.items() if k != "labels"}
    h, aux = M.score(MCFG, params, inputs, use_window=ccfg.use_window,
                     train=True, impl=ccfg.impl)
    f = objective.auc_F(h, batch["labels"], a, b, alpha, ccfg.p_pos)
    return f + ccfg.moe_aux_coef * aux


def _legacy_average(state, compress=None):
    """Pre-refactor ``coda.average``: params tree + the three named scalars."""
    if compress == "int8":
        def avg(x):
            xf = x.astype(jnp.float32)
            q, scale = coda.int8_quantize(xf, tuple(range(1, x.ndim)))
            deq = q.astype(jnp.float32) * scale
            m = jnp.mean(deq, axis=0, keepdims=True)
            return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    else:
        avg = lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True),
                                         x.shape)
    new = dict(state)
    new["params"] = jax.tree_util.tree_map(avg, state["params"])
    for k in ("a", "b", "alpha"):
        new[k] = avg(state[k])
    return new


@pytest.mark.parametrize("compress", ["", "int8"])
def test_auc_refactor_matches_legacy_coda_window(compress):
    """objective="auc" through the generic dual-tree path must reproduce
    the pre-refactor scalar-field window (I local steps + averaging,
    fp32/int8) over multiple windows, to fp32 tolerance."""
    K, I = 4, 3
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7, avg_compress=compress)
    key = jax.random.PRNGKey(0)
    st_new = coda.init_state(key, MCFG, ccfg)
    st_old = _legacy_state(st_new)
    for seed in range(3):
        wb = _window(jax.random.PRNGKey(seed), I, K)
        st_new, losses_new = coda.window_step(MCFG, ccfg, st_new, wb, 0.1)
        losses_old = []
        for i in range(I):
            st_old, ls, _ = _legacy_local_step(
                ccfg, st_old, jax.tree_util.tree_map(lambda l: l[i], wb), 0.1)
            losses_old.append(jnp.mean(ls))
        st_old = _legacy_average(st_old, compress or None)
        np.testing.assert_allclose(np.asarray(losses_new),
                                   np.asarray(jnp.stack(losses_old)),
                                   atol=1e-6)
        assert _max_err(st_new["params"], st_old["params"]) < 1e-6
        for f in ("a", "b", "alpha"):
            assert float(jnp.max(jnp.abs(st_new["duals"][f] - st_old[f]))) \
                < 1e-6, (compress, f)


def test_auc_refactor_matches_legacy_codasca_window():
    """The CODASCA variant of the pin: legacy per-field control variates
    (cv_a/cg_a/... scalar fields, fp32 raw-gradient accumulator, combined
    refresh) vs the generic ``cv_duals``/``cg_duals`` trees — exact over
    multiple heterogeneous windows."""
    K, I = 4, 2
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7, algorithm="codasca")
    key = jax.random.PRNGKey(1)
    st_new = coda.init_state(key, MCFG, ccfg)
    base = coda.CoDAConfig(n_workers=K, p_pos=0.7)
    leg = _legacy_state(st_new)
    zt = lambda: jax.tree_util.tree_map(jnp.zeros_like, leg["params"])
    zk = lambda: jnp.zeros_like(leg["a"])
    leg.update(cv_params=zt(), cg_params=zt())
    for f in ("a", "b", "alpha"):
        leg[f"cv_{f}"], leg[f"cg_{f}"] = zk(), zk()

    for seed in range(3):
        wb = _window(jax.random.PRNGKey(10 + seed), I, K)
        st_new, _ = codasca.window_step(MCFG, ccfg, st_new, wb, 0.1)

        # legacy window: corrected steps + fp32 accumulator + refresh
        acc_p = jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, jnp.float32), leg["params"])
        acc = {"a": zk(), "b": zk(), "alpha": zk()}
        for i in range(I):
            b_i = jax.tree_util.tree_map(lambda l: l[i], wb)
            corr = lambda g, c, ck: g + (c - ck)
            vg = jax.value_and_grad(
                lambda p_, a_, b_, al_, bt_: _legacy_worker_loss(
                    base, p_, a_, b_, al_, bt_), argnums=(0, 1, 2, 3))
            _, (gp, ga, gb, gal) = jax.vmap(vg)(
                leg["params"], leg["a"], leg["b"], leg["alpha"], b_i)
            gp_c = jax.tree_util.tree_map(corr, gp, leg["cg_params"],
                                          leg["cv_params"])
            ga_c = corr(ga, leg["cg_a"], leg["cv_a"])
            gb_c = corr(gb, leg["cg_b"], leg["cv_b"])
            gal_c = corr(gal, leg["cg_alpha"], leg["cv_alpha"])
            new_params = kops.prox_update_tree(leg["params"], gp_c,
                                               leg["ref_params"], 0.1,
                                               base.gamma)
            prox = lambda v, g, v0: (base.gamma * (v - 0.1 * g)
                                     + 0.1 * v0) / (0.1 + base.gamma)
            leg["params"] = new_params
            leg["a"] = prox(leg["a"], ga_c, leg["ref_a"])
            leg["b"] = prox(leg["b"], gb_c, leg["ref_b"])
            leg["alpha"] = leg["alpha"] + 0.1 * gal_c
            acc_p = jax.tree_util.tree_map(
                lambda s, g: s + g.astype(jnp.float32), acc_p, gp)
            for f, g in (("a", ga), ("b", gb), ("alpha", gal)):
                acc[f] = acc[f] + g.astype(jnp.float32)
        cvp = jax.tree_util.tree_map(
            lambda g, w: (g / I).astype(w.dtype), acc_p, leg["params"])
        cvs = {f: acc[f] / I for f in acc}
        leg = _legacy_average(leg)
        mean0 = lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True),
                                           x.shape)
        leg["cg_params"] = jax.tree_util.tree_map(mean0, cvp)
        leg["cv_params"] = cvp
        for f in ("a", "b", "alpha"):
            leg[f"cg_{f}"] = mean0(cvs[f])
            leg[f"cv_{f}"] = cvs[f]

        assert _max_err(st_new["params"], leg["params"]) < 1e-6
        for f in ("a", "b", "alpha"):
            assert float(jnp.max(jnp.abs(st_new["duals"][f] - leg[f]))) < 1e-6
            assert float(jnp.max(jnp.abs(
                st_new["cv_duals"][f] - leg[f"cv_{f}"]))) < 1e-6
            assert float(jnp.max(jnp.abs(
                st_new["cg_duals"][f] - leg[f"cg_{f}"]))) < 1e-6
        assert _max_err(st_new["cv_params"], leg["cv_params"]) < 1e-6
        assert _max_err(st_new["cg_params"], leg["cg_params"]) < 1e-6


# --------------------------------------------------------------------------
# pAUC-DRO objective properties
# --------------------------------------------------------------------------
def _pauc_obj(**kw):
    return objective.PAUCDROObjective(p_pos=0.7, **kw)


def test_pauc_loss_gradients_match_finite_differences():
    obj = _pauc_obj()
    key = jax.random.PRNGKey(0)
    h = jax.random.uniform(key, (64,))
    y = (jax.random.uniform(jax.random.PRNGKey(1), (64,)) < 0.7).astype(jnp.float32)
    duals = {"a": jnp.float32(0.2), "b": jnp.float32(0.3),
             "alpha": jnp.float32(0.1), "lam": jnp.float32(0.7)}
    gh, gd = jax.grad(lambda h_, d_: obj.loss(h_, y, d_), argnums=(0, 1))(h, duals)
    eps = 1e-3

    def fd(f, x):
        return (f(x + eps) - f(x - eps)) / (2 * eps)

    # a few h coordinates (one positive, one negative)
    for i in (int(jnp.argmax(y)), int(jnp.argmin(y))):
        num = fd(lambda v: float(obj.loss(h.at[i].set(v), y, duals)), float(h[i]))
        assert abs(num - float(gh[i])) < 5e-3, (i, num, float(gh[i]))
    for f in ("a", "b", "alpha", "lam"):
        num = fd(lambda v: float(obj.loss(h, y, {**duals, f: jnp.float32(v)})),
                 float(duals[f]))
        assert abs(num - float(gd[f])) < 5e-3, (f, num, float(gd[f]))


def test_pauc_dro_weights_concentrate_as_lam_shrinks():
    """The implicit DRO weights q_j ∝ exp(ℓ_j/λ): small λ concentrates the
    negative-side gradient mass on the hardest negatives, large λ spreads
    it uniformly — measured through ∂F/∂h on the negative coordinates."""
    obj = _pauc_obj()
    key = jax.random.PRNGKey(2)
    h = jax.random.uniform(key, (128,))
    y = jnp.zeros((128,))  # all negatives isolates the DRO side
    duals = lambda lam: {"a": jnp.float32(0.0), "b": jnp.float32(0.0),
                         "alpha": jnp.float32(0.0), "lam": jnp.float32(lam)}

    def neg_grad_entropy(lam):
        g = jax.grad(lambda h_: obj.loss(h_, y, duals(lam)))(h)
        w = jnp.abs(g) / jnp.sum(jnp.abs(g))
        return float(-jnp.sum(w * jnp.log(w + 1e-12)))

    assert neg_grad_entropy(0.05) < neg_grad_entropy(0.5) < neg_grad_entropy(50.0)


def test_pauc_all_positive_batch_is_finite():
    """Dirichlet-starved shards produce all-positive batches; the DRO
    log-sum-exp over zero negatives must yield finite loss AND gradients
    (the double-where guard — a single where leaks NaN grads)."""
    obj = _pauc_obj()
    h = jnp.linspace(0.1, 0.9, 16)
    y = jnp.ones((16,))
    duals = {"a": jnp.float32(0.1), "b": jnp.float32(0.2),
             "alpha": jnp.float32(0.3), "lam": jnp.float32(1.0)}
    val, (gh, gd) = jax.value_and_grad(
        lambda h_, d_: obj.loss(h_, y, d_), argnums=(0, 1))(h, duals)
    assert np.isfinite(float(val))
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree_util.tree_leaves((gh, gd)))
    upd = obj.stage_duals(h, y, duals)
    assert np.isfinite(float(upd["alpha"]))


def test_pauc_lam_projected_at_floor():
    obj = _pauc_obj()
    duals = obj.init_duals(4)
    grads = {f: jnp.full((4,), 100.0) for f in duals}   # huge descent pull
    refs = {f: jnp.zeros((4,)) for f in obj.prox_refs}
    new = obj.dual_step(duals, grads, refs, eta=1.0, gamma=0.5)
    np.testing.assert_allclose(np.asarray(new["lam"]),
                               np.full(4, obj.lam_min), atol=0)
    # ascent field went UP, prox fields pulled toward the (zero) reference
    assert float(new["alpha"][0]) > float(duals["alpha"][0])
    assert abs(float(new["a"][0])) < 100.0


def test_pauc_trains_through_both_window_paths():
    K, I = 4, 2
    for alg in ("coda", "codasca"):
        ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7, algorithm=alg,
                               objective="pauc_dro")
        st = coda.init_state(jax.random.PRNGKey(0), MCFG, ccfg)
        assert set(st["duals"]) == {"a", "b", "alpha", "lam"}
        wstep = codasca.window_step if alg == "codasca" else coda.window_step
        for seed in range(2):
            st, losses = wstep(MCFG, ccfg, st, _window(
                jax.random.PRNGKey(seed), I, K), 0.1)
            assert np.isfinite(np.asarray(losses)).all()
        st = coda.stage_end(MCFG, ccfg, st, jax.tree_util.tree_map(
            lambda l: l[0], _window(jax.random.PRNGKey(9), I, K)),
            resync=False)
        # λ never left the feasible set; payload counts the 4th dual
        assert float(jnp.min(st["duals"]["lam"])) >= 0.05
        base = coda.init_state(jax.random.PRNGKey(0), MCFG,
                               coda.CoDAConfig(n_workers=K, p_pos=0.7,
                                               algorithm=alg))
        assert coda.model_bytes(st) == coda.model_bytes(base) + 4


# --------------------------------------------------------------------------
# server momentum
# --------------------------------------------------------------------------
def test_server_momentum_zero_is_plain_path_bitwise():
    K, I = 4, 2
    c0 = coda.CoDAConfig(n_workers=K, p_pos=0.7)
    cz = coda.CoDAConfig(n_workers=K, p_pos=0.7, server_momentum=0.0)
    st0 = coda.init_state(jax.random.PRNGKey(0), MCFG, c0)
    stz = coda.init_state(jax.random.PRNGKey(0), MCFG, cz)
    assert "srv_m" not in stz            # β = 0 adds no state field
    wb = _window(jax.random.PRNGKey(1), I, K)
    s0, l0 = coda.window_step(MCFG, c0, st0, wb, 0.1)
    sz, lz = coda.window_step(MCFG, cz, stz, wb, 0.1)
    assert _max_err(s0, sz) == 0.0
    assert float(jnp.max(jnp.abs(l0 - lz))) == 0.0


def test_server_momentum_matches_manual_recursion():
    """β > 0: over two windows the executor must match the hand-rolled
    m_t = β·m_{t-1} + (x̄_t − x_{t-1}),  x_t = x_{t-1} + m_t  recursion
    built from plain (momentum-free) window averages."""
    K, I, beta = 4, 2, 0.6
    cm = coda.CoDAConfig(n_workers=K, p_pos=0.7, server_momentum=beta)
    c0 = coda.CoDAConfig(n_workers=K, p_pos=0.7)
    st = coda.init_state(jax.random.PRNGKey(0), MCFG, cm)
    m = st["srv_m"]
    plain = {k: v for k, v in st.items() if k != "srv_m"}
    for seed in range(2):
        wb = _window(jax.random.PRNGKey(seed), I, K)
        st, _ = coda.window_step(MCFG, cm, st, wb, 0.1)
        x_start = plain["params"]
        bar, _ = coda.window_step(MCFG, c0, plain, wb, 0.1)
        m = jax.tree_util.tree_map(
            lambda m_, xb, xs: beta * m_ + (xb.astype(jnp.float32)
                                            - xs.astype(jnp.float32)),
            m, bar["params"], x_start)
        want_x = jax.tree_util.tree_map(
            lambda xs, m_: (xs.astype(jnp.float32) + m_), x_start, m)
        assert _max_err(st["params"], want_x) < 1e-6
        assert _max_err(st["srv_m"], m) < 1e-6
        plain = dict(bar)
        plain["params"] = st["params"]   # momentum trajectory continues
        plain["duals"] = st["duals"]


def test_server_momentum_not_in_wire_payload():
    """The momentum buffer is server-side state: the payload accounting —
    and hence the HLO payload asserts built on it — must not change."""
    K = 4
    cm = coda.CoDAConfig(n_workers=K, p_pos=0.7, server_momentum=0.9)
    c0 = coda.CoDAConfig(n_workers=K, p_pos=0.7)
    sm = coda.init_state(jax.random.PRNGKey(0), MCFG, cm)
    s0 = coda.init_state(jax.random.PRNGKey(0), MCFG, c0)
    assert coda.model_bytes(sm) == coda.model_bytes(s0)
    assert coda.window_payload_bytes(sm) == coda.window_payload_bytes(s0)
    assert coda.window_payload_by_dtype(sm) == coda.window_payload_by_dtype(s0)


def test_config_rejects_bad_objective_and_momentum():
    with pytest.raises(ValueError):
        coda.CoDAConfig(n_workers=2, objective="AUC")
    with pytest.raises(ValueError):
        coda.CoDAConfig(n_workers=2, server_momentum=1.0)
    with pytest.raises(ValueError):
        coda.CoDAConfig(n_workers=2, pauc_beta=0.0)


# --------------------------------------------------------------------------
# the BCE seam (dual-free objective)
# --------------------------------------------------------------------------
def test_bce_step_matches_manual_formula():
    """baselines.bce_step routes through the objective seam — it must
    compute exactly the logit-space-BCE parallel-SGD step."""
    K, B = 3, 16
    key = jax.random.PRNGKey(0)
    params = baselines.bce_init(key, MCFG, K)
    wb = jax.tree_util.tree_map(lambda l: l[0], _window(key, 1, K, B))
    new_params, loss = baselines.bce_step(MCFG, params, wb, 0.1)

    def manual(p, b):
        inputs = {k: v for k, v in b.items() if k != "labels"}
        h, aux = M.score(MCFG, p, inputs, train=True)
        y = b["labels"]
        return -jnp.mean(y * jax.nn.log_sigmoid(h)
                         + (1 - y) * jax.nn.log_sigmoid(-h)) + 0.01 * aux

    losses, grads = jax.vmap(jax.value_and_grad(manual))(params, wb)
    grads = jax.tree_util.tree_map(
        lambda g: jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True), g.shape),
        grads)
    want = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g, params, grads)
    assert abs(float(loss) - float(jnp.mean(losses))) < 1e-7
    assert _max_err(new_params, want) < 1e-7


def test_bce_loss_is_logit_space():
    """The vanishing-gradient regression: BCEObjective.loss consumes the
    UNBOUNDED score logit.  The old form clipped h into (1e-6, 1-1e-6) and
    took logs — any score outside (0, 1) saturated the clip and its
    gradient was exactly zero.  Pin the loss against the explicit
    sigmoid+log oracle and the gradient against (σ(h) − y)/n, which never
    vanishes at finite logits."""
    obj = objective.REGISTRY["bce"](p_pos=0.5)
    h = jnp.asarray([-5.0, -0.3, 0.2, 4.0])
    y = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    sig = 1.0 / (1.0 + np.exp(-np.asarray(h)))
    want = -np.mean(np.asarray(y) * np.log(sig)
                    + (1 - np.asarray(y)) * np.log(1 - sig))
    got = float(obj.loss(h, y, {}))
    assert abs(got - want) < 1e-6
    grad = np.asarray(jax.grad(lambda h: obj.loss(h, y, {}))(h))
    np.testing.assert_allclose(grad, (sig - np.asarray(y)) / 4, rtol=1e-5)
    # the fix's point: the pre-fix clip zeroed the gradient at h=-5 and h=4
    assert np.abs(grad).min() > 1e-4


def test_bce_objective_trains_with_empty_dual_tree():
    """objective="bce" through the CoDA executors: empty duals, zero dual
    payload, zero stage bytes — the generic tree plumbing's empty limit."""
    K, I = 4, 2
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7, objective="bce")
    st = coda.init_state(jax.random.PRNGKey(0), MCFG, ccfg)
    assert st["duals"] == {} and st["ref_duals"] == {}
    wb = _window(jax.random.PRNGKey(1), I, K)
    st, losses = coda.window_step(MCFG, ccfg, st, wb, 0.1)
    assert np.isfinite(np.asarray(losses)).all()
    st = coda.stage_end(MCFG, ccfg, st, jax.tree_util.tree_map(
        lambda l: l[0], wb), resync=False)
    params_only = sum(l.size // K * 4 for l in
                      jax.tree_util.tree_leaves(st["params"]))
    assert coda.model_bytes(st) == params_only
    assert coda.stage_payload_bytes(ccfg) == 0


# --------------------------------------------------------------------------
# sharded path for the new objective (subprocess: 8 forced host devices)
# --------------------------------------------------------------------------
_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.analysis import audit as A
    from repro.analysis import hlo as H
    from repro.configs.base import mlp_config
    from repro.core import coda, codasca
    mcfg = mlp_config(n_features=16, d=32)

    def make_case(K, I, B=8, seed=0, **kw):
        ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7, **kw)
        key = jax.random.PRNGKey(seed)
        st0 = coda.init_state(key, mcfg, ccfg)
        ky, kx = jax.random.split(key)
        y = (jax.random.uniform(ky, (I, K, B)) < 0.7).astype(jnp.float32)
        x = jax.random.normal(kx, (I, K, B, 16)) + 0.3 * (y[..., None] * 2 - 1)
        wb = {"features": x, "labels": y}
        ab = {k: v[0] for k, v in wb.items()}
        return ccfg, st0, wb, ab

    def assert_trees_close(got, want, tol, label):
        for (p, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(got)[0],
                                  jax.tree_util.tree_flatten_with_path(want)[0]):
            err = float(jnp.max(jnp.abs(a - b)))
            assert err < tol, (label, jax.tree_util.keystr(p), err)
""")


def _run_sub(script: str, timeout=900):
    r = subprocess.run([sys.executable, "-c", _PRELUDE + textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "ALL OK" in r.stdout, r.stdout[-2000:]


def test_pauc_dro_shard_map_matches_oracle_and_payload():
    """The CI matrix's --objective pauc_dro case: the sharded executor runs
    the 4-field dual tree (coda AND codasca, and with server momentum) to
    oracle equivalence, the compiled window stays ONE all-reduce of the
    generic payload (model_bytes counts the extra λ dual), and the stage
    boundary still ships one fp32 scalar (α only)."""
    _run_sub("""
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    K, I = 8, 3
    for label, kw in [
        ("coda", dict(objective="pauc_dro")),
        ("codasca", dict(objective="pauc_dro", algorithm="codasca")),
        ("coda+momentum", dict(objective="pauc_dro", server_momentum=0.5)),
    ]:
        ccfg, st0, wb, ab = make_case(K, I, **kw)
        exe = coda.make_executor(mcfg, ccfg, "shard_map", mesh=mesh,
                                 donate=False)
        st = exe.place(st0)
        rt = st0
        wstep = codasca.window_step if ccfg.algorithm == "codasca" \\
            else coda.window_step
        for _ in range(2):
            st, losses = exe.window_step(st, wb, 0.1)
            rt, rl = wstep(mcfg, ccfg, rt, wb, 0.1)
        st2 = exe.stage_end(st, ab)
        rt2 = coda.stage_end(mcfg, ccfg, rt, ab, resync=False)
        assert_trees_close(st, rt, 1e-5, label + "/window")
        assert_trees_close(st2, rt2, 1e-5, label + "/stage")
        np.testing.assert_allclose(np.asarray(jnp.mean(losses, axis=1)),
                                   np.asarray(rl), atol=1e-5)

        payload = coda.window_payload_bytes(st0)
        txt = exe.window_fn(st0, wb).lower(
            st0, wb, jnp.float32(0.1)).compile().as_text()
        A.assert_window_payload(txt, payload)
        stxt = exe.stage_fn(st0, ab).lower(st0, ab).compile().as_text()
        sops = H.collective_ops(stxt)
        assert len(sops) == 1 and sops[0]["bytes"] == 4, sops
        print("OK", label, "payload", payload)
    # the 4th dual really is on the wire: +4 bytes vs the AUC payload
    c_auc, s_auc, _, _ = make_case(K, I)
    assert coda.model_bytes(st0) == coda.model_bytes(s_auc) + 4
    print("ALL OK")
    """)
