"""Unit tests for the analysis/hlo.py parser on hand-written HLO snippets.

The parser regexes were historically exercised only through end-to-end
compiles, which never emit some shapes the backends CAN produce — async
``-start``/``-done`` pairs (a latency-hiding scheduler splits every
collective), tuple-shaped collective results, nested tuple types on
multi-operand async ops.  These snippets pin the contract directly:
an async pair counts ONCE with the result component's bytes, a ``-done``
line never matches, tuple results sum their components.
"""
import pytest

from repro.analysis import hlo as H


def _ops(text):
    return H.collective_ops(text)


def test_sync_all_reduce_counts_once():
    txt = "%ar = f32[256]{0} all-reduce(%p0), replica_groups={{0,1}}"
    (op,) = _ops(txt)
    assert op["op"] == "all-reduce"
    assert op["bytes"] == 256 * 4
    assert op["by_dtype"] == {"f32": 1024}
    assert op["replica_groups"] == "{{0,1}}"


def test_async_pair_counts_once_from_start():
    """A scheduler-split collective is ONE logical op: the -start line is
    the record, the -done line matches nothing."""
    txt = "\n".join([
        "%ar-start = (f32[128]{0}, f32[128]{0}) all-reduce-start(%p0)",
        "%unrelated = f32[128]{0} add(%a, %b)",
        "%ar-done = f32[128]{0} all-reduce-done(%ar-start)",
    ])
    ops = _ops(txt)
    assert len(ops) == 1
    assert ops[0]["op"] == "all-reduce"
    # the (operand, result) tuple must not double the bytes
    assert ops[0]["bytes"] == 128 * 4


def test_done_line_alone_never_matches():
    txt = "%ar-done = f32[64]{0} all-reduce-done(%ar-start)"
    assert _ops(txt) == []


def test_async_all_gather_start():
    txt = ("%ag-start = (s8[100]{0}, s8[800]{0}) all-gather-start(%p0), "
           "replica_groups={{0,1,2,3,4,5,6,7}}")
    (op,) = _ops(txt)
    assert op["op"] == "all-gather"
    assert op["by_dtype"] == {"s8": 800}    # gathered size, not the operand


def test_async_multi_operand_nested_tuple():
    """Combined async collectives carry ((operands...), (results...)) —
    only the results component counts, summed across its members."""
    txt = ("%ar-start = ((f32[16]{0}, s8[32]{0}), (f32[16]{0}, s8[32]{0})) "
           "all-reduce-start(%a, %b)")
    (op,) = _ops(txt)
    assert op["by_dtype"] == {"f32": 64, "s8": 32}
    assert op["bytes"] == 96


def test_tuple_shaped_sync_result_sums_components():
    """A non-async tuple-result collective reduces every component — all of
    them are wire bytes."""
    txt = "%ar = (f32[8]{0}, f32[24]{0}) all-reduce(%a, %b)"
    (op,) = _ops(txt)
    assert op["bytes"] == (8 + 24) * 4


def test_collective_permute_and_mixed_kinds():
    txt = "\n".join([
        "%cp = bf16[64]{0} collective-permute(%x), "
        "source_target_pairs={{0,1},{1,0}}",
        "%rs = f32[32]{0} reduce-scatter(%y), replica_groups={{0,1}}",
    ])
    ops = _ops(txt)
    assert [o["op"] for o in ops] == ["collective-permute", "reduce-scatter"]
    assert ops[0]["by_dtype"] == {"bf16": 128}


def test_non_collective_lines_ignored():
    txt = "\n".join([
        "%d = f32[8,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}",
        "%allreduce_like_name = f32[8]{0} add(%a, %b)",
        "%fusion.all-reduce.1 = f32[8]{0} fusion(%a), kind=kLoop",
    ])
    assert _ops(txt) == []


def test_tuple_components_splitter():
    assert H._tuple_components("f32[8]") == ["f32[8]"]
    assert H._tuple_components("(f32[8], s8[4])") == ["f32[8]", "s8[4]"]
    assert H._tuple_components("((f32[8], s8[4]), (f32[8], s8[4]))") == \
        ["(f32[8], s8[4])", "(f32[8], s8[4])"]


def test_collective_bytes_totals():
    txt = "\n".join([
        "%ar-start = (f32[128]{0}, f32[128]{0}) all-reduce-start(%p0)",
        "%ar-done = f32[128]{0} all-reduce-done(%ar-start)",
        "%ag = s8[800]{0} all-gather(%q)",
    ])
    out = H.collective_bytes(txt)
    assert out["all-reduce"] == {"bytes": 512, "count": 1,
                                 "by_dtype": {"f32": 512}}
    assert out["all-gather"]["bytes"] == 800
    assert out["total_count"] == 2
    assert out["total_bytes"] == 1312


def test_verify_window_payload_on_async_snippet():
    """The delegating wrapper sees through the async split: one logical
    all-reduce of the expected bytes."""
    txt = "\n".join([
        "%ar-start = (f32[100]{0}, f32[100]{0}) all-reduce-start(%p0)",
        "%ar-done = f32[100]{0} all-reduce-done(%ar-start)",
    ])
    H.verify_window_payload(txt, 400)
    with pytest.raises(AssertionError, match="payload mismatch"):
        H.verify_window_payload(txt, 800)
