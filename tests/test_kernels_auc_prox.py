"""auc_loss + prox_update Pallas kernels vs oracles and vs autodiff, with
hypothesis property sweeps on the paper's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.auc_loss import auc_loss
from repro.kernels.prox_update import prox_update


def _case(key, T, p_frac=0.5):
    kh, ky = jax.random.split(key)
    h = jax.random.uniform(kh, (T,))
    y = (jax.random.uniform(ky, (T,)) < p_frac).astype(jnp.float32)
    return h, y


@pytest.mark.parametrize("T,block", [(64, 32), (100, 32), (1024, 256),
                                     (7, 8), (513, 128)])
@pytest.mark.parametrize("p", [0.5, 0.71])
def test_auc_kernel_vs_ref(T, block, p):
    h, y = _case(jax.random.PRNGKey(T), T, p)
    a, b, alpha = 0.3, 0.2, -0.1
    got = auc_loss(h, y, a, b, alpha, p, block=block, interpret=True)
    exp = ref.auc_loss_ref(h, y, a, b, alpha, p)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e),
                                   atol=1e-5, rtol=1e-4)


def test_auc_ref_vs_autodiff():
    """Closed-form partials must equal autodiff of the direct F expression."""
    h, y = _case(jax.random.PRNGKey(1), 257, 0.6)
    p = 0.7

    def direct(h, a, b, alpha):
        pos = y
        neg = 1 - y
        f = ((1 - p) * (h - a) ** 2 * pos + p * (h - b) ** 2 * neg
             + 2 * (1 + alpha) * (p * h * neg - (1 - p) * h * pos)
             - p * (1 - p) * alpha ** 2)
        return jnp.mean(f)

    a, b, alpha = 0.4, 0.1, 0.25
    grads = jax.grad(direct, argnums=(0, 1, 2, 3))(h, a, b, alpha)
    loss, dh, da, db, dalpha = ref.auc_loss_ref(h, y, a, b, alpha, p)
    np.testing.assert_allclose(np.asarray(loss), direct(h, a, b, alpha), atol=1e-6)
    np.testing.assert_allclose(np.asarray(dh), np.asarray(grads[0]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(da), np.asarray(grads[1]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(db), np.asarray(grads[2]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(dalpha), np.asarray(grads[3]), atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(p=st.floats(0.05, 0.95), alpha=st.floats(-2.0, 2.0),
       seed=st.integers(0, 2 ** 16))
def test_auc_strong_concavity_in_alpha(p, alpha, seed):
    """F is 2p(1-p)-strongly concave in α (the paper's μ_α): the closed-form
    α* = E[h|−]−E[h|+] maximizes it."""
    h, y = _case(jax.random.PRNGKey(seed), 128, 0.5)
    if float(y.sum()) in (0.0, 128.0):
        return
    f = lambda al: ref.auc_loss_ref(h, y, 0.1, 0.2, al, p)[0]
    # NOTE F uses prior p while α* uses the batch composition; with the exact
    # gradient condition: dF/dα(α_opt)=0 where α_opt solves the p-weighted
    # problem.  Check concavity + stationarity of the p-weighted optimum.
    g = jax.grad(f)
    alpha_opt = float(jnp.sum(2 * (p * h * (1 - y) - (1 - p) * h * y)) /
                      (2 * p * (1 - p) * h.shape[0]))
    assert abs(float(g(alpha_opt))) < 1e-4
    assert float(f(alpha_opt)) >= float(f(alpha)) - 1e-5


@pytest.mark.parametrize("N,block", [(128, 64), (1000, 256), (5, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prox_kernel_vs_ref(N, block, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(N), 3)
    v = jax.random.normal(k1, (N,), dtype)
    g = jax.random.normal(k2, (N,), dtype)
    v0 = jax.random.normal(k3, (N,), dtype)
    got = prox_update(v, g, v0, 0.05, 0.5, block=block, interpret=True)
    exp = ref.prox_update_ref(v, g, v0, 0.05, 0.5)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32), atol=2e-2
                               if dtype == jnp.bfloat16 else 1e-6)


@settings(max_examples=30, deadline=None)
@given(eta=st.floats(1e-4, 1.0), gamma=st.floats(1e-3, 10.0),
       seed=st.integers(0, 2 ** 16))
def test_prox_is_argmin(eta, gamma, seed):
    """The update must minimize u ↦ g·u + ‖u−v‖²/(2η) + ‖u−v₀‖²/(2γ)
    (footnote 1 of the paper) — verify the first-order condition."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    v = jax.random.normal(k1, (16,))
    g = jax.random.normal(k2, (16,))
    v0 = jax.random.normal(k3, (16,))
    u = ref.prox_update_ref(v, g, v0, eta, gamma)
    foc = g + (u - v) / eta + (u - v0) / gamma
    # fp32 roundoff in u is amplified by 1/η + 1/γ in the optimality residual
    tol = 3e-6 * (1 / eta + 1 / gamma) + 1e-5
    np.testing.assert_allclose(np.asarray(foc), 0.0, atol=tol)
