"""Sort-based dropless MoE dispatch (models/moe.py ``dispatch="sorted"``).

Pins: (1) sorted dispatch ≡ a dense one-hot einsum oracle, forward AND
gradient, in fp32; (2) sorted ≡ the ``capacity`` path whenever nothing
drops (eval C = T is dropless by construction); (3) the grouped-GEMM Pallas
kernel ≡ its blocked-scan jnp reference on ragged/empty/unaligned segments;
(4) the all-k load-balance aux loss reduces to the classic top-1 count at
k = 1 and actually counts both slots at k = 2; (5) the dispatch-buffer
accounting the moe_dispatch benchmark reports."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.configs.base import ModelConfig, MoEConfig
from repro.kernels import ref
from repro.kernels.moe_dispatch import grouped_matmul
from repro.models import moe


def _moe_cfg(E, k, d=16, ff=32, dispatch="sorted", capacity_factor=1.25):
    return ModelConfig(
        name="tiny-moe", family="moe", n_layers=1, d_model=d, n_heads=2,
        n_kv_heads=2, d_ff=ff, vocab_size=64,
        moe=MoEConfig(n_experts=E, top_k=k, dispatch=dispatch,
                      capacity_factor=capacity_factor))


def _dense_oracle(cfg, p, x):
    """Dense one-hot einsum MoE: every expert sees every token, combine
    weights select — the O(E·T) semantics oracle for any dispatch."""
    m = cfg.moe
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    top_g, top_e, _ = moe.route(cfg, p, xf)
    comb = jnp.sum(jax.nn.one_hot(top_e, m.n_experts, dtype=jnp.float32)
                   * top_g[..., None], axis=1)              # [T, E]
    h = jnp.einsum("td,edf->tef", xf, p["w_gate"])
    u = jnp.einsum("td,edf->tef", xf, p["w_up"])
    ye = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["w_down"])
    out = jnp.einsum("te,ted->td", comb, ye)
    return out.reshape(B, S, d).astype(x.dtype)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), E=st.sampled_from([2, 4, 8]),
       k=st.integers(1, 3), T=st.integers(1, 33))
def test_sorted_matches_dense_oracle_forward_and_grad(seed, E, k, T):
    cfg = _moe_cfg(E, min(k, E))
    key = jax.random.PRNGKey(seed)
    kp, kx = jax.random.split(key)
    p = moe.init_moe(kp, cfg)
    x = jax.random.normal(kx, (1, T, cfg.d_model), jnp.float32) * 0.5

    got, _ = moe.apply_moe(cfg, p, x)
    want = _dense_oracle(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)

    # gradient w.r.t. inputs and every expert weight (fp32)
    tang = jax.random.normal(kx, got.shape)
    g_got = jax.grad(lambda p, x: jnp.sum(moe.apply_moe(cfg, p, x)[0] * tang),
                     argnums=(0, 1))(p, x)
    g_want = jax.grad(lambda p, x: jnp.sum(_dense_oracle(cfg, p, x) * tang),
                      argnums=(0, 1))(p, x)
    for a, b in zip(jax.tree_util.tree_leaves(g_got),
                    jax.tree_util.tree_leaves(g_want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), arch=st.sampled_from(
    ["dbrx-132b", "arctic-480b"]), T=st.integers(1, 40))
def test_sorted_matches_capacity_when_dropless(seed, arch, T):
    """Eval-mode capacity dispatch (C = T) never drops, so the two modes
    must agree on identical routing decisions."""
    cfg = get_smoke_config(arch)
    cfg_cap = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="capacity"))
    key = jax.random.PRNGKey(seed)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(key, (2, T, cfg.d_model), jnp.float32) * 0.5
    got, aux_s = moe.apply_moe(cfg, p, x)
    want, aux_c = moe.apply_moe(cfg_cap, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    assert float(aux_s) == float(aux_c)  # routing (and aux) bitwise-shared


def test_grouped_matmul_kernel_matches_ref():
    """Interpret-mode Pallas kernel vs the jnp reference on ragged segments:
    empty experts, tile-unaligned sizes, trailing empty groups."""
    key = jax.random.PRNGKey(0)
    for gs in ([3, 0, 6, 1], [0, 0, 10, 0], [10, 0, 0, 0], [1, 2, 3, 4]):
        gs = jnp.asarray(gs, jnp.int32)
        N = int(gs.sum())
        kx, kw, key = jax.random.split(key, 3)
        x = jax.random.normal(kx, (N, 7))
        w = jax.random.normal(kw, (4, 7, 5))
        want = ref.grouped_matmul_ref(x, w, gs)
        got = grouped_matmul(x, w, gs, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


def test_grouped_matmul_kernel_small_blocks():
    """Block sizes smaller than segments force multi-tile experts."""
    key = jax.random.PRNGKey(1)
    gs = jnp.asarray([5, 9, 0, 2], jnp.int32)
    x = jax.random.normal(key, (16, 4))
    w = jax.random.normal(key, (4, 4, 6))
    want = ref.grouped_matmul_ref(x, w, gs)
    got = grouped_matmul(x, w, gs, block_m=8, block_n=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_aux_loss_k1_equals_top1_count():
    """At k = 1 the all-k dispatched-fraction count must equal the classic
    Switch top-1 formulation exactly."""
    cfg = _moe_cfg(4, 1)
    key = jax.random.PRNGKey(2)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    _, aux = moe.apply_moe(cfg, p, x)
    xf = x.reshape(-1, cfg.d_model)
    _, top_e, gates = moe.route(cfg, p, xf)
    me = jnp.mean(gates, axis=0)
    ce_top1 = jnp.mean(jax.nn.one_hot(top_e[:, 0], 4, dtype=jnp.float32),
                       axis=0)
    want = 4 * jnp.sum(me * ce_top1)
    assert float(aux) == pytest.approx(float(want), abs=0)


def test_aux_loss_counts_all_k_slots():
    """A router biased to always pick experts {0, 1} as the top-2 pair must
    report HALF the dispatch mass on each — the slot-0-only count would
    blame only the argmax expert."""
    cfg = _moe_cfg(4, 2)
    key = jax.random.PRNGKey(3)
    p = moe.init_moe(key, cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]).at[:, 0].set(1.0)
             .at[:, 1].set(0.999))  # every token routes to (0, 1)
    x = jnp.abs(jax.random.normal(key, (1, 32, cfg.d_model))) + 0.5
    xf = x.reshape(-1, cfg.d_model)
    _, top_e, _ = moe.route(cfg, p, xf)
    assert set(np.unique(np.asarray(top_e))) == {0, 1}
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, 4, dtype=jnp.float32), axis=1),
                  axis=0) / 2
    np.testing.assert_allclose(np.asarray(ce), [0.5, 0.5, 0.0, 0.0],
                               atol=1e-6)


def test_dispatch_buffer_bytes_accounting():
    """The acceptance numbers: sorted = T·k·d vs capacity C=T = E·T·d —
    an E/top_k-fold gap (64× on the real arctic-480b config, well past the
    required E/(2·top_k))."""
    from repro.configs import get_config
    cfg = get_config("arctic-480b")
    T = 32768
    s = moe.dispatch_buffer_bytes(cfg, T, mode="sorted")
    c = moe.dispatch_buffer_bytes(cfg, T, mode="capacity")
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    assert s == T * k * cfg.d_model * 4
    assert c == E * moe.capacity(cfg, T, train=False) * cfg.d_model * 4
    assert c / s >= E / (2 * k)
    with pytest.raises(ValueError):
        moe.dispatch_buffer_bytes(cfg, T, mode="dense")


def test_moe_config_rejects_unknown_dispatch():
    with pytest.raises(ValueError):
        MoEConfig(n_experts=4, top_k=2, dispatch="scatter")


def test_prefill_matches_parallel_scoring_moe():
    """Token-by-token prefill through serve_step (sorted dispatch at T = B
    per step) must reproduce the parallel forward's last-token logits."""
    from repro.models import init_params
    from repro.models import model as M
    from repro.serving import decode as D
    cfg = get_smoke_config("dbrx-132b")
    assert cfg.moe.dispatch == "sorted"
    key = jax.random.PRNGKey(4)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    cache = D.init_cache(cfg, 2, 12, use_window=False, dtype=jnp.float32)
    _, got = D.prefill(cfg, params, cache, tokens, use_window=False)
    h, _ = M.backbone(cfg, params, {"tokens": tokens})
    want = M.lm_logits(cfg, params, h[:, -1])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)
