"""Decode-vs-parallel consistency: feeding a sequence token-by-token through
``serve_step`` (KV caches / recurrent states) must reproduce the hidden state
of the parallel (train/prefill) forward — per family, including the ring
buffer and the chunkwise-mLSTM/recurrent-mLSTM pair."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.models import model as M
from repro.models import xlstm as xl
from repro.serving import decode as D


def _decode_last_logits(cfg, params, tokens, use_window):
    B, S = tokens.shape
    cache = D.init_cache(cfg, B, S, use_window=use_window, dtype=jnp.float32)
    logits = None
    for t in range(S):
        logits, _, cache = D.serve_step(cfg, params, cache, tokens[:, t:t + 1],
                                        jnp.full((B,), t, jnp.int32),
                                        use_window=use_window)
    return logits


def _parallel_last_logits(cfg, params, tokens, use_window):
    h, _ = M.backbone(cfg, params, {"tokens": tokens}, use_window=use_window)
    return M.lm_logits(cfg, params, h[:, -1])


@pytest.mark.parametrize("arch,use_window", [
    ("qwen2.5-14b", False),
    ("chatglm3-6b", False),       # 2d RoPE + GQA kv=2
    ("stablelm-1.6b", False),     # partial rotary, layernorm, MHA
    ("dbrx-132b", False),         # MoE top-2 of 4
    ("hymba-1.5b", True),         # window rings + mamba state + global layers
    ("xlstm-350m", False),        # mLSTM chunkwise vs recurrent + sLSTM scan
])
def test_decode_matches_parallel(arch, use_window):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 24), 0, cfg.vocab_size)
    got = _decode_last_logits(cfg, params, tokens, use_window)
    exp = _parallel_last_logits(cfg, params, tokens, use_window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ["dbrx-132b", "arctic-480b"])
@pytest.mark.parametrize("dispatch", ["sorted", "capacity"])
def test_moe_decode_matches_parallel_both_dispatches(arch, dispatch):
    """MoE archs under BOTH eval dispatch modes: the sorted dropless path
    (decode sees T = B tokens per step, parallel sees T = B·S — routing must
    agree with itself at every token count) and the capacity C = T oracle."""
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch=dispatch))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 24), 0, cfg.vocab_size)
    got = _decode_last_logits(cfg, params, tokens, use_window=False)
    exp = _parallel_last_logits(cfg, params, tokens, use_window=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=2e-3, rtol=2e-3)


def test_ring_buffer_matches_windowed_attention():
    """Sequence longer than the ring: decode through a W-slot ring must equal
    the parallel forward with sliding-window masking."""
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-14b"), window=8,
                              window_mode="optional")
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (1, 24), 0, cfg.vocab_size)
    got = _decode_last_logits(cfg, params, tokens, use_window=True)
    exp = _parallel_last_logits(cfg, params, tokens, use_window=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=2e-3, rtol=2e-3)


def test_mlstm_chunkwise_equals_recurrent():
    cfg = get_smoke_config("xlstm-350m")
    key = jax.random.PRNGKey(2)
    p = xl.init_mlstm(key, cfg)
    x = jax.random.normal(key, (2, 32, cfg.d_model)) * 0.5
    par = xl.apply_mlstm(cfg, p, x, chunk=8)
    state = xl.init_mlstm_state(cfg, 2)
    outs = []
    for t in range(32):
        o, state = xl.decode_mlstm(cfg, p, state, x[:, t:t + 1])
        outs.append(o)
    rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(par), np.asarray(rec),
                               atol=2e-4, rtol=2e-3)


def test_mlstm_chunk_size_invariance():
    cfg = get_smoke_config("xlstm-350m")
    key = jax.random.PRNGKey(3)
    p = xl.init_mlstm(key, cfg)
    x = jax.random.normal(key, (1, 64, cfg.d_model)) * 0.5
    a = xl.apply_mlstm(cfg, p, x, chunk=64)
    b = xl.apply_mlstm(cfg, p, x, chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4,
                               rtol=2e-3)


def test_ssm_decode_matches_parallel():
    from repro.models.ssm import apply_ssm, decode_ssm, init_ssm, init_ssm_state
    cfg = get_smoke_config("hymba-1.5b")
    key = jax.random.PRNGKey(4)
    p = init_ssm(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.5
    par = apply_ssm(cfg, p, x)
    state = init_ssm_state(cfg, 2)
    outs = []
    for t in range(16):
        o, state = decode_ssm(cfg, p, state, x[:, t:t + 1])
        outs.append(o)
    rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(par), np.asarray(rec),
                               atol=1e-4, rtol=1e-3)


def test_encdec_decode_matches_parallel():
    cfg = get_smoke_config("seamless-m4t-medium")
    key = jax.random.PRNGKey(5)
    params = init_params(key, cfg)
    B, Se = 2, 16
    Sd = Se // cfg.decoder_fraction  # the decoder self-cache is sized S//4
    frames = jax.random.normal(key, (B, Se, cfg.d_model))
    tokens = jax.random.randint(key, (B, Sd), 0, cfg.vocab_size)
    h, _ = M.backbone(cfg, params, {"frames": frames, "tokens": tokens})
    exp = M.lm_logits(cfg, params, h[:, -1])

    cache = D.init_cache(cfg, B, Se, use_window=False, dtype=jnp.float32)
    cache = D.encode_for_decode(cfg, params, cache, frames)
    logits = None
    for t in range(Sd):
        logits, _, cache = D.serve_step(cfg, params, cache, tokens[:, t:t + 1],
                                        jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(exp),
                               atol=2e-3, rtol=2e-3)
