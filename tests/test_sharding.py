"""Sharding-rule tests: divisibility guards, expected specs for known leaves,
and a real (small-mesh) lowering of the CoDA window step with collectives
appearing only at the averaging boundary."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import hlo as H
from repro.configs import get_config, get_smoke_config
from repro.core import coda
from repro.launch import mesh as MESH
from repro.sharding import rules as R


@pytest.fixture(scope="module")
def host_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _specs_for(arch, mesh, policy, worker_axes=()):
    mcfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda k: __import__("repro.models.model", fromlist=["m"]).init_params(
            k, mcfg, dtype=jnp.bfloat16),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    return {jax.tree_util.keystr(p): R.param_spec(p, l, mesh, policy,
                                                  worker_axes=worker_axes)
            for p, l in flat}, shapes


def test_known_specs_serving_layout():
    """Params without a worker axis (the serving path)."""
    mesh = MESH.abstract_mesh((1, 4, 2), ("pod", "data", "model"))
    specs, _ = _specs_for("qwen2.5-14b", mesh, "replica")
    assert specs["['layers']['attn']['wq']"] == P(None, None, "model")
    assert specs["['layers']['attn']['wo']"] == P(None, "model", None)
    assert specs["['layers']['mlp']['w_down']"] == P(None, "model", None)
    assert specs["['embed']['table']"] == P("model", None)
    assert specs["['layers']['norm1']['scale']"] == P(None, None)


def test_known_specs_coda_state_layout():
    """The stacked-worker CoDA state: leading K over the worker axes."""
    mesh = MESH.abstract_mesh((2, 4, 2), ("pod", "data", "model"))
    mcfg = get_smoke_config("qwen2.5-14b")
    ccfg = coda.CoDAConfig(n_workers=8)
    state_shapes = jax.eval_shape(lambda k: coda.init_state(k, mcfg, ccfg),
                                  jax.ShapeDtypeStruct((2,), jnp.uint32))
    sh = R.state_shardings(state_shapes, mesh, "replica", multi_pod=True)
    wq = sh["params"]["layers"]["attn"]["wq"].spec
    assert wq == P(("pod", "data"), None, None, "model")
    assert sh["duals"]["alpha"].spec == P(("pod", "data"))
    assert sh["params"]["score_head"]["w"].spec[0] == ("pod", "data")


def test_moe_expert_parallel_specs():
    mesh = MESH.abstract_mesh((2, 4, 2), ("pod", "data", "model"))
    specs, _ = _specs_for("arctic-480b", mesh, "fsdp")
    # experts [L, E, d, ff]: E over data, ff over model
    assert specs["['layers']['moe']['w_gate']"] == P(None, "data", None, "model")
    assert specs["['layers']['moe']['w_down']"] == P(None, "data", "model", None)
    # the dense residual MLP is NOT expert-sharded (FSDP d over data)
    assert specs["['layers']['moe']['dense']['w_gate']"] == P(None, "data", "model")
    assert specs["['layers']['moe']['router']"] == P(None, None, None)


def test_divisibility_guard_drops_axes():
    """internvl2's vocab 92553 is not divisible by 16 — must replicate."""
    mesh = MESH.abstract_mesh((1, 4, 4), ("pod", "data", "model"))
    specs, shapes = _specs_for("internvl2-2b", mesh, "replica")
    assert specs["['embed']['table']"][0] is None  # 92553 % 4 != 0
    # while attention stays sharded
    assert specs["['layers']['attn']['wq']"][-1] == "model"


def test_worker_count_policy():
    mesh1 = MESH.abstract_mesh((16, 16), ("data", "model"))
    mesh2 = MESH.abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert MESH.n_workers(mesh1, "replica") == 16
    assert MESH.n_workers(mesh2, "replica") == 32
    assert MESH.n_workers(mesh1, "fsdp") == 1
    assert MESH.n_workers(mesh2, "fsdp") == 2
    assert R.policy_for("arctic-480b") == "fsdp"
    assert R.policy_for("qwen2.5-14b") == "replica"


_LOWERING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro import flags
    flags.DRYRUN_UNROLL = True  # honest per-iteration FLOP counting
    from repro.analysis import hlo as H
    from repro.configs import get_smoke_config
    from repro.core import coda
    from repro.sharding import rules as R

    mesh = jax.make_mesh((2, 1), ("data", "model"))
    mcfg = get_smoke_config("stablelm-1.6b")
    ccfg = coda.CoDAConfig(n_workers=2, p_pos=0.7)

    def lower(I):
        state_shapes = jax.eval_shape(
            lambda k: coda.init_state(k, mcfg, ccfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        batch = {
            "tokens": jax.ShapeDtypeStruct((I, 2, 4, 32), jnp.int32),
            "labels": jax.ShapeDtypeStruct((I, 2, 4), jnp.float32),
        }
        st_sh = R.state_shardings(state_shapes, mesh, "replica", multi_pod=False)
        bt_sh = R.batch_shardings(batch, mesh, "replica", multi_pod=False)
        fn = lambda st, wb, eta: coda.window_step(mcfg, ccfg, st, wb, eta)
        with mesh:
            lowered = jax.jit(fn, in_shardings=(st_sh, bt_sh, None),
                              out_shardings=(st_sh, None)).lower(
                state_shapes, batch, jax.ShapeDtypeStruct((), jnp.float32))
        comp = lowered.compile()
        ca = comp.cost_analysis()
        if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per partition
            ca = ca[0]
        coll = H.collective_bytes(comp.as_text())
        return float(ca.get("flops", 0)), coll["total_bytes"]

    f1, c1 = lower(1)
    f4, c4 = lower(4)
    assert f4 > 3.0 * f1, (f1, f4)            # compute scales with I
    assert c4 < 2.0 * max(c1, 1), (c1, c4)    # communication does not
    assert c1 > 0                             # ...and exists at all
    print("OK", f1, f4, c1, c4)
""")


def test_collectives_scale_with_window_length():
    """Lower the CoDA window step on a 2-worker mesh (subprocess — needs
    forced host devices): the all-reduce bytes must be (approximately)
    independent of I — that IS the paper's point — while FLOPs grow linearly
    with I."""
    r = subprocess.run([sys.executable, "-c", _LOWERING_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_hlo_collective_parser():
    txt = """
  %all-reduce.1 = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %x), replica_groups={}
  %ag = bf16[4,256]{1,0} all-gather(bf16[4,128]{1,0} %y), dimensions={1}
  %fusion.1 = f32[16]{0} fusion(f32[16]{0} %z)
  %a2a = (f32[8]{0}, f32[8]{0}) all-to-all(f32[8]{0} %p, f32[8]{0} %q)
"""
    c = H.collective_bytes(txt)
    assert c["all-reduce"]["bytes"] == 16 * 128 * 4
    assert c["all-gather"]["bytes"] == 4 * 256 * 2
    assert c["all-to-all"]["bytes"] == 2 * 8 * 4
    assert c["all-reduce"]["count"] == 1
    assert c["total_count"] == 3
