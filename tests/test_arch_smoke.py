"""Deliverable (f): per assigned-architecture smoke tests on REDUCED
same-family variants (≤2 layers, d_model ≤ 512, ≤4 experts): one forward and
one CoDA train step on CPU, asserting output shapes and no NaNs; plus one
serve_step decode token where the family has a decode path."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core import coda
from repro.models import init_params, score
from repro.serving import decode as D

B, S = 2, 64


def _batch(cfg, lead, key):
    kt, kp = jax.random.split(key)
    out = {}
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(kp, lead + (cfg.n_patches, cfg.d_model))
        out["tokens"] = jax.random.randint(kt, lead + (S - cfg.n_patches,), 0,
                                           cfg.vocab_size)
    elif cfg.family == "audio":
        out["frames"] = jax.random.normal(kp, lead + (S, cfg.d_model))
        out["tokens"] = jax.random.randint(kt, lead + (S // cfg.decoder_fraction,),
                                           0, cfg.vocab_size)
    elif cfg.family == "cnn":
        out["images"] = jax.random.normal(kp, lead + (1024, 3))
    else:
        out["tokens"] = jax.random.randint(kt, lead + (S,), 0, cfg.vocab_size)
    out["labels"] = (jax.random.uniform(kp, lead) < 0.7).astype(jnp.float32)
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_coda_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 or cfg.family == "cnn"
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, (B,), key)
    h, aux = score(cfg, params, {k: v for k, v in batch.items() if k != "labels"})
    assert h.shape == (B,)
    assert bool(jnp.all(jnp.isfinite(h))) and bool(jnp.all((h >= 0) & (h <= 1)))

    K = 2
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=0.7)
    state = coda.init_state(key, cfg, ccfg)
    wb = _batch(cfg, (1, K, B), key)
    state, losses = coda.window_step(cfg, ccfg, state, wb, 0.05)
    for leaf in jax.tree_util.tree_leaves(state):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch
    assert bool(jnp.all(jnp.isfinite(losses)))


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS if a != "resnet50"])
def test_serve_step_one_token(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    cache = D.init_cache(cfg, B, 32, use_window=True, dtype=jnp.float32)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    pos = jnp.zeros((B,), jnp.int32)
    logits, score_logit, cache2 = D.serve_step(cfg, params, cache, tok, pos)
    assert logits.shape == (B, cfg.vocab_size)
    assert score_logit.shape == (B,)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # a second token must also work against the updated cache
    logits2, _, _ = D.serve_step(cfg, params, cache2, tok, pos + 1)
    assert bool(jnp.all(jnp.isfinite(logits2)))
