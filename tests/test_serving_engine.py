"""Regression + behaviour tests for the continuous-batching serving engine.

Each of the hardening fixes in ``serving/engine.py`` lands with a test that
fails on the pre-fix engine:

  * empty prompts used to livelock ``run()`` (slot admitted, nothing to feed,
    silent return with the request never finalized) — now rejected at the
    door, and ``run()`` raises ``TicksExhausted`` instead of returning
    silently when ticks run out with work left;
  * prompts longer than ``max_len`` used to wrap their cache writes back to
    position 0 (``positions % window``), silently corrupting the slot — now
    validated at admission (truncate, recorded on the request, or reject);
  * ``_reset_slot`` used to skip any cache leaf without an ``.at`` attribute
    (``hasattr`` guard), leaving e.g. numpy leaves of a host-roundtripped
    cache permanently stale — now every leaf is reset and a leaf that does
    not carry the slot axis at dim 0 raises.

Plus the engine behaviours the bugfixes hang off: tick accounting for
batched chunked prefill, FIFO/SJF admission, queue bounds, eos termination,
deadline expiry, prefix-cache exactness, and chunk-size invariance of the
generated tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import decode as D
from repro.serving.engine import Request, ServingEngine, TicksExhausted


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg_params, **kw):
    cfg, params = cfg_params
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 48)
    return ServingEngine(cfg, params, **kw)


def _serve_alone(cfg_params, prompt, max_new, **kw):
    eng = _engine(cfg_params, slots=1, **kw)
    req = Request(uid=0, prompt=list(prompt), max_new_tokens=max_new)
    eng.add_request(req)
    eng.run()
    return req


# --------------------------------------------------------------------------
# admission-time validation (the hang/overflow fixes)
# --------------------------------------------------------------------------
def test_empty_prompt_rejected_at_admission(cfg_params):
    """Pre-fix: an empty prompt was admitted to a slot with nothing to feed
    and nothing generated — run() spun to max_ticks and returned with the
    request still not done."""
    eng = _engine(cfg_params)
    req = Request(uid=0, prompt=[], max_new_tokens=4)
    assert eng.add_request(req) is False
    assert req.status == "rejected" and req.reject_reason == "empty_prompt"
    assert req.done
    # the engine is still fully serviceable afterwards
    ok = Request(uid=1, prompt=[5, 6, 7], max_new_tokens=2)
    assert eng.add_request(ok) is True
    eng.run()
    assert ok.status == "done" and len(ok.generated) == 2


def test_non_positive_budget_rejected(cfg_params):
    eng = _engine(cfg_params)
    req = Request(uid=0, prompt=[1, 2], max_new_tokens=0)
    assert eng.add_request(req) is False
    assert req.reject_reason == "non_positive_max_new_tokens"


def test_run_raises_when_ticks_exhausted(cfg_params):
    """Pre-fix: run() silently returned with requests still in flight."""
    eng = _engine(cfg_params, prefill_chunk=1)
    eng.add_request(Request(uid=0, prompt=list(range(1, 30)),
                            max_new_tokens=8))
    with pytest.raises(TicksExhausted):
        eng.run(max_ticks=3)


def test_overlong_prompt_truncated_and_exact(cfg_params):
    """Pre-fix: a prompt longer than max_len wrapped its cache writes back
    to position 0 (positions % window), silently corrupting the slot and
    producing tokens from a scrambled cache.  Now the prompt is truncated
    at admission (recorded on the request) and the generated tokens match
    serving the truncated prompt alone."""
    cfg, _ = cfg_params
    max_len = 16
    prompt = list(np.random.RandomState(0).randint(
        1, cfg.vocab_size, size=40))
    req = Request(uid=0, prompt=prompt, max_new_tokens=4)
    eng = _engine(cfg_params, slots=1, max_len=max_len)
    assert eng.add_request(req) is True
    eng.run()
    assert req.truncated and req.status == "done"
    assert req.prompt_used == prompt[:max_len - 1]
    ref = _serve_alone(cfg_params, prompt[:max_len - 1], 4, max_len=max_len)
    assert req.generated == ref.generated


def test_overlong_prompt_rejected_under_reject_policy(cfg_params):
    eng = _engine(cfg_params, max_len=16, on_overflow="reject")
    req = Request(uid=0, prompt=list(range(1, 41)), max_new_tokens=4)
    assert eng.add_request(req) is False
    assert req.reject_reason == "prompt_too_long"


# --------------------------------------------------------------------------
# slot recycling (the stale-slot fix)
# --------------------------------------------------------------------------
def test_reset_slot_resets_numpy_leaves(cfg_params):
    """Pre-fix regression: ``hasattr(old, "at")`` silently skipped numpy
    leaves (a cache restored from host memory), leaving the slot's state
    stale for the next request.  Every leaf must reset."""
    cfg, _ = cfg_params
    eng = _engine(cfg_params, slots=2, max_len=32)
    req = Request(uid=0, prompt=list(range(1, 20)), max_new_tokens=4)
    eng.add_request(req)
    eng.run()
    # host-roundtrip the cache (e.g. a checkpoint restore): all numpy leaves
    eng.cache = jax.tree_util.tree_map(np.asarray, eng.cache)
    eng.cache = eng._reset_slot(0)
    fresh = D.init_cache(cfg, 1, 32, use_window=True, dtype=jnp.float32)
    for got, want in zip(jax.tree_util.tree_leaves(eng.cache),
                         jax.tree_util.tree_leaves(fresh)):
        np.testing.assert_array_equal(np.asarray(got[0:1]), np.asarray(want))


def test_reset_slot_raises_on_slotless_leaf(cfg_params):
    """A cache leaf that does not carry the slot axis at dim 0 violates the
    engine-wide contract and must raise, not be silently skipped."""
    eng = _engine(cfg_params, slots=2)
    leaves, treedef = jax.tree_util.tree_flatten(eng.cache)
    leaves[0] = leaves[0][0]   # drop the slot axis on one leaf
    eng.cache = jax.tree_util.tree_unflatten(treedef, leaves)
    with pytest.raises(ValueError, match="slot axis"):
        eng._reset_slot(0)


def test_slot_recycling_is_exact(cfg_params):
    """A short request served in a slot previously occupied by a long one
    generates exactly what it generates alone."""
    eng = _engine(cfg_params, slots=1, max_len=48)
    long = Request(uid=0, prompt=list(range(1, 41)), max_new_tokens=6)
    short = Request(uid=1, prompt=[7, 11, 13], max_new_tokens=4)
    eng.add_request(long)
    eng.add_request(short)
    eng.run()
    assert long.status == "done" and short.status == "done"
    ref = _serve_alone(cfg_params, [7, 11, 13], 4)
    assert short.generated == ref.generated


# --------------------------------------------------------------------------
# batched chunked prefill
# --------------------------------------------------------------------------
def test_tick_accounting(cfg_params):
    """One slot, prompt of 20, chunk of 8, 4 new tokens: prefill takes
    ceil(20/8)=3 ticks (the first token comes out of the last prefill
    tick), decode takes the remaining 3."""
    eng = _engine(cfg_params, slots=1, max_len=48, prefill_chunk=8)
    req = Request(uid=0, prompt=list(range(1, 21)), max_new_tokens=4)
    eng.add_request(req)
    eng.run()
    assert req.status == "done" and len(req.generated) == 4
    assert eng.ticks == 6
    assert eng.tokens_prefilled == 20
    assert eng.tokens_decoded == 3


def test_chunked_prefill_matches_token_per_tick(cfg_params):
    """The tentpole's exactness claim: generated tokens are invariant to
    prefill_chunk, including heterogeneous prompt lengths sharing a tick."""
    prompts = [list(range(1, 25)), [3, 1, 4, 1, 5], list(range(40, 9, -1))]
    outs = {}
    for chunk in (1, 8):
        eng = _engine(cfg_params, slots=2, max_len=48, prefill_chunk=chunk)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.add_request(r)
        eng.run()
        outs[chunk] = [r.generated for r in reqs]
    assert outs[1] == outs[8]


# --------------------------------------------------------------------------
# admission order, bounds, termination, deadlines
# --------------------------------------------------------------------------
def test_fifo_admission_order_and_queue_bound(cfg_params):
    eng = _engine(cfg_params, slots=1, queue_limit=2, prefill_chunk=4)
    reqs = [Request(uid=i, prompt=[i + 1, i + 2], max_new_tokens=1)
            for i in range(3)]
    assert eng.add_request(reqs[0]) is True
    assert eng.add_request(reqs[1]) is True
    assert eng.add_request(reqs[2]) is False      # bounded queue
    assert reqs[2].reject_reason == "queue_full"
    eng.run()
    assert reqs[0].t_admitted <= reqs[1].t_admitted
    assert [r.status for r in reqs[:2]] == ["done", "done"]


def test_sjf_admits_short_job_first(cfg_params):
    eng = _engine(cfg_params, slots=1, admission="sjf")
    long = Request(uid=0, prompt=list(range(1, 30)), max_new_tokens=1)
    short = Request(uid=1, prompt=[5, 6], max_new_tokens=1)
    eng.add_request(long)
    eng.add_request(short)
    eng.run()
    assert short.t_admitted < long.t_admitted


def test_eos_terminates_decode(cfg_params):
    probe = _serve_alone(cfg_params, [2, 3, 5, 8], 1)
    g0 = probe.generated[0]
    req = Request(uid=0, prompt=[2, 3, 5, 8], max_new_tokens=8, eos_id=g0)
    eng = _engine(cfg_params, slots=1)
    eng.add_request(req)
    eng.run()
    assert req.generated == [g0] and req.status == "done"


def test_deadline_expires_queued_and_active(cfg_params):
    clk = {"t": 0.0}
    eng = _engine(cfg_params, slots=1, prefill_chunk=2,
                  clock=lambda: clk["t"])
    slow = Request(uid=0, prompt=list(range(1, 30)), max_new_tokens=8,
                   deadline=5.0)
    queued = Request(uid=1, prompt=[4, 5], max_new_tokens=2, deadline=1.0)
    eng.add_request(slow)
    eng.add_request(queued)
    eng.step()
    clk["t"] = 2.0      # past queued's deadline, inside slow's
    eng.step()
    assert queued.status == "expired" and queued.done
    clk["t"] = 6.0      # now past slow's too
    eng.step()
    assert slow.status == "expired"
    assert eng.n_expired == 2
    assert all(r is None for r in eng.active) and not eng.queue


def test_latency_accounting_fields(cfg_params):
    clk = {"t": 0.0}
    eng = _engine(cfg_params, slots=1, prefill_chunk=8,
                  clock=lambda: clk["t"])
    req = Request(uid=0, prompt=[1, 2, 3], max_new_tokens=2)
    eng.add_request(req)
    clk["t"] = 1.0
    eng.run()
    assert req.t_arrival == 0.0 and req.t_admitted == 1.0
    assert req.ttft == 1.0 and req.latency == 1.0
    assert req.score is not None and np.isfinite(req.score)


# --------------------------------------------------------------------------
# prefix cache
# --------------------------------------------------------------------------
def test_prefix_cache_hit_is_exact(cfg_params):
    """B's prompt extends A's completed prompt: B must hit the prefix cache
    for len(A) tokens and still generate exactly what it generates alone."""
    base = list(range(1, 13))
    ext = base + [17, 19, 23]
    eng = _engine(cfg_params, slots=1, prefix_cache_size=4)
    a = Request(uid=0, prompt=base, max_new_tokens=2)
    eng.add_request(a)
    eng.run()
    b = Request(uid=1, prompt=ext, max_new_tokens=4)
    eng.add_request(b)
    eng.run()
    assert b.prefix_hit_tokens == len(base)
    assert eng.prefix_hits == 1
    ref = _serve_alone(cfg_params, ext, 4)
    assert b.generated == ref.generated


def test_prefix_cache_miss_on_disjoint_prompt(cfg_params):
    eng = _engine(cfg_params, slots=1, prefix_cache_size=4)
    a = Request(uid=0, prompt=list(range(1, 13)), max_new_tokens=1)
    eng.add_request(a)
    eng.run()
    b = Request(uid=1, prompt=[40, 41, 42, 43], max_new_tokens=1)
    eng.add_request(b)
    eng.run()
    assert b.prefix_hit_tokens == 0
    assert eng.prefix_misses >= 1


# --------------------------------------------------------------------------
# streaming metrics over served traffic
# --------------------------------------------------------------------------
def test_streaming_metric_accumulates_labeled_requests(cfg_params):
    """With a metric attached and a labeled trace, every finalized scored
    request folds into the engine's streaming state, and the sketch AUC
    agrees with the exact metric over the same served (score, label) pairs
    within the sketch's resolution bound (+1e-6 fp slack: the oracle's
    f32 score arithmetic carries ~1e-7 noise of its own)."""
    from repro.metrics import streaming
    from repro.serving import loadgen as LG

    met = streaming.make_metric("auc", "sketch", bins=256)
    eng = _engine(cfg_params, slots=2, metric=met)
    tcfg = LG.TraceConfig(kind="batch", n_requests=12, prompt_len=(6, 20),
                          max_new=(1, 3), labeled=True, seed=5)
    cfg, _ = cfg_params
    reqs, wall = LG.run_trace(eng, LG.make_trace(tcfg, cfg.vocab_size))
    assert eng.n_scored == 12
    assert all(r.label in (0.0, 1.0) for r in reqs)
    sm = eng.streaming_metrics()
    assert sm["metric"] == "auc" and sm["backend"] == "sketch"
    assert sm["scored"] == 12 and sm["state_bytes"] == 2 * 256 * 4
    exact = streaming.make_metric("auc", "exact").compute(
        np.asarray([r.score for r in reqs], np.float32),
        np.asarray([r.label for r in reqs], np.float32))
    assert abs(sm["value"] - exact) <= sm["resolution"] + 1e-6
    m = LG.summarize(reqs, wall, eng)
    assert m["streaming_auc"] == sm["value"]
    assert m["streaming_scored"] == 12


def test_streaming_metric_ignores_unlabeled_requests(cfg_params):
    from repro.metrics import streaming
    from repro.serving import loadgen as LG

    eng = _engine(cfg_params, slots=2,
                  metric=streaming.make_metric("auc", "exact"))
    cfg, _ = cfg_params
    tcfg = LG.TraceConfig(kind="batch", n_requests=4, prompt_len=(6, 20),
                          max_new=(1, 3), seed=1)  # labeled=False
    reqs, wall = LG.run_trace(eng, LG.make_trace(tcfg, cfg.vocab_size))
    assert eng.n_scored == 0
    assert eng.streaming_metrics()["value"] == 0.0
    # no metric attached -> no streaming rows at all
    eng2 = _engine(cfg_params, slots=2)
    assert eng2.streaming_metrics() is None
    assert "streaming_auc" not in LG.summarize(reqs, wall, eng2)


def test_labeled_trace_is_seed_deterministic(cfg_params):
    from repro.serving import loadgen as LG

    cfg, _ = cfg_params
    tcfg = LG.TraceConfig(kind="batch", n_requests=6, labeled=True, seed=9)
    a = LG.make_trace(tcfg, cfg.vocab_size)
    b = LG.make_trace(tcfg, cfg.vocab_size)
    assert [(r.prompt, r.label) for _, r in a] \
        == [(r.prompt, r.label) for _, r in b]
    c = LG.make_trace(LG.TraceConfig(kind="batch", n_requests=6,
                                     labeled=True, seed=10), cfg.vocab_size)
    assert [(r.prompt, r.label) for _, r in a] \
        != [(r.prompt, r.label) for _, r in c]
    with pytest.raises(ValueError, match="p_pos"):
        LG.TraceConfig(labeled=True, p_pos=1.5)


# --------------------------------------------------------------------------
# per-request failure isolation (the fault-tolerance hardening)
# --------------------------------------------------------------------------
class _ExplodingList(list):
    """A generated-token buffer that blows up on first append — simulates a
    per-request failure while consuming the scored device output."""

    def append(self, tok):
        raise RuntimeError("scorer exploded")


def test_scoring_failure_finalizes_request_not_engine(cfg_params):
    """Pre-fix: an exception while consuming one slot's output unwound
    step() mid-loop — the failed request hung in its slot forever and every
    other active slot lost that tick's token.  Now the failure finalizes
    THAT request (status 'failed', reason recorded, latency accounting
    intact, slot freed) and the rest of the trace keeps serving."""
    eng = _engine(cfg_params, slots=2)
    bad = Request(uid=0, prompt=[3, 4, 5], max_new_tokens=4)
    bad.generated = _ExplodingList()
    ok = Request(uid=1, prompt=[6, 7, 8, 9], max_new_tokens=3)
    assert eng.add_request(bad) and eng.add_request(ok)
    eng.run()
    assert bad.status == "failed" and bad.done
    assert "RuntimeError" in bad.failure_reason
    assert "scorer exploded" in bad.failure_reason
    assert bad.latency is not None          # t_complete stamped
    assert eng.n_failed == 1
    # the healthy request is untouched by its neighbour's failure
    assert ok.status == "done" and len(ok.generated) == 3
    # the failed slot is recycled, not leaked
    late = Request(uid=2, prompt=[11, 12], max_new_tokens=2)
    assert eng.add_request(late) is True
    eng.run()
    assert late.status == "done"
    # and the loadgen summary surfaces the failure count
    from repro.serving import loadgen as LG
    rec = LG.summarize([bad, ok, late], wall=1.0)
    assert rec["failed"] == 1 and rec["completed"] == 2


def test_ticks_exhausted_carries_partial_records(cfg_params):
    """TicksExhausted is a report, not just a signal: it carries the
    partial per-request records (uid, status, tokens so far, prompt
    progress, latency stamps) of everything still in flight."""
    eng = _engine(cfg_params, slots=1, prefill_chunk=1, queue_limit=8)
    eng.add_request(Request(uid=0, prompt=list(range(1, 20)),
                            max_new_tokens=8))
    eng.add_request(Request(uid=1, prompt=[2, 3], max_new_tokens=2))
    with pytest.raises(TicksExhausted) as ei:
        eng.run(max_ticks=3)
    recs = ei.value.records
    assert [r["uid"] for r in recs] == [0, 1]
    by_uid = {r["uid"]: r for r in recs}
    assert by_uid[0]["status"] == "active"
    assert by_uid[0]["prompt_consumed"] == 3       # one token per tick
    assert by_uid[0]["generated"] == []
    assert by_uid[1]["status"] == "queued"
    assert by_uid[1]["prompt_consumed"] == 0
    assert by_uid[0]["t_admitted"] is not None
    assert by_uid[1]["t_admitted"] is None
    # default construction still works (records optional)
    assert TicksExhausted("plain").records == []


def test_metric_fold_failure_keeps_served_outcome(cfg_params):
    """A broken streaming-metric fold must not un-serve the request: the
    'done' outcome stands, the fault is recorded on the request, and the
    metric simply stops accumulating."""
    class _BrokenMetric:
        name, backend = "auc", "broken"

        def init(self):
            return {}

        def update(self, state, scores, labels):
            raise ValueError("sketch overflow")

    eng = _engine(cfg_params, slots=1, metric=_BrokenMetric())
    req = Request(uid=0, prompt=[4, 5, 6], max_new_tokens=2, label=1.0)
    eng.add_request(req)
    eng.run()
    assert req.status == "done" and len(req.generated) == 2
    assert req.failure_reason.startswith("metric: ValueError")
    assert eng.n_scored == 0 and eng.n_failed == 0
