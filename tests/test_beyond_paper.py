"""Beyond-paper optimization knobs (§Perf): int8 compressed worker
averaging and the quantized KV cache must preserve accuracy within their
documented tolerances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import coda
from repro.models import init_params, model as M
from repro.serving import decode as D


def test_int8_average_close_to_exact():
    key = jax.random.PRNGKey(0)
    mcfg = get_smoke_config("stablelm-1.6b")
    ccfg = coda.CoDAConfig(n_workers=4)
    st = coda.init_state(key, mcfg, ccfg)
    # create worker disagreement (what averaging actually reconciles)
    st = jax.tree_util.tree_map(
        lambda x: x + 0.01 * jax.random.normal(key, x.shape, x.dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, st)
    exact = coda.average(st)
    q = coda.average(st, compress="int8")
    for l1, l2 in zip(jax.tree_util.tree_leaves(exact["params"]),
                      jax.tree_util.tree_leaves(q["params"])):
        scale = float(jnp.max(jnp.abs(l1))) + 1e-9
        assert float(jnp.max(jnp.abs(l1 - l2))) / scale < 0.02


def test_int8_average_is_synced():
    key = jax.random.PRNGKey(1)
    mcfg = get_smoke_config("qwen2.5-14b")
    ccfg = coda.CoDAConfig(n_workers=3, avg_compress="int8")
    st = coda.init_state(key, mcfg, ccfg)
    wb = {"tokens": jax.random.randint(key, (1, 3, 4, 32), 0, mcfg.vocab_size),
          "labels": jnp.ones((1, 3, 4), jnp.float32)}
    st2, _ = coda.window_step(mcfg, ccfg, st, wb, 0.05)
    for l in jax.tree_util.tree_leaves(st2["params"]):
        assert float(jnp.max(jnp.abs(l - l[0:1]))) == 0.0


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "chatglm3-6b"])
def test_int8_kv_cache_decode(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    cache = D.init_cache(cfg, 2, 16, use_window=False, dtype=jnp.int8)
    logits = None
    for t in range(16):
        logits, _, cache = D.serve_step(cfg, params, cache,
                                        tokens[:, t:t + 1],
                                        jnp.full((2,), t, jnp.int32))
    h, _ = M.backbone(cfg, params, {"tokens": tokens})
    exp = M.lm_logits(cfg, params, h[:, -1])
    rel = float(jnp.max(jnp.abs(logits - exp))) / (
        float(jnp.max(jnp.abs(exp))) + 1e-9)
    assert rel < 0.05, rel
    # and top-1 agreement (what greedy decode cares about)
    agree = float(jnp.mean((jnp.argmax(logits, -1) == jnp.argmax(exp, -1))
                           .astype(jnp.float32)))
    assert agree == 1.0
