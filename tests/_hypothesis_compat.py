"""Import shim: real ``hypothesis`` when installed, a tiny fixed-seed
fallback otherwise, so the tier-1 suite collects and runs in a clean env
(no pip access) while keeping full property-based shrinking wherever the
real library is available.

Usage in tests (drop-in for the hypothesis triple):

    from _hypothesis_compat import given, settings, strategies as st

The fallback samples ``max_examples`` pseudo-random examples from a
deterministic ``random.Random(0)`` stream — no shrinking, no database,
but the same parameter names and decorator stacking order
(``@settings`` above ``@given``) as the tests already use.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # fixed-seed fallback
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elements.example(rng)
                for _ in range(rng.randint(min_size, max_size))])

    def given(**strats):
        def deco(f):
            # NOT functools.wraps: pytest must see a zero-arg signature, or
            # it would treat the strategy parameters as fixtures.
            def wrapper():
                rng = random.Random(0)
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    ex = {k: s.example(rng) for k, s in strats.items()}
                    f(**ex)

            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            wrapper.__module__ = f.__module__
            wrapper._max_examples = 10
            wrapper.hypothesis_fallback = True
            return wrapper

        return deco

    def settings(max_examples=10, deadline=None, **_ignored):
        def deco(f):
            f._max_examples = max_examples
            return f

        return deco


st = strategies
