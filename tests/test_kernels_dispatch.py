"""Backend dispatch for the Pallas kernel wrappers (kernels/ops.py).

The bug this pins down: a non-TPU backend must NEVER be handed
interpret-mode Pallas by the "auto" path — interpret mode is a correctness
tool, orders of magnitude slower than either a real kernel or the jnp
reference, so "auto" routes every non-TPU backend to kernels/ref.py and
only the explicit ``impl="pallas"`` override may interpret off-TPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("backend,impl,want", [
    # (use_pallas, interpret) per (backend, impl)
    ("tpu", "auto", (True, False)),     # real kernel on TPU
    ("gpu", "auto", (False, False)),    # GPU: XLA reference, NOT interpret
    ("cpu", "auto", (False, False)),    # CPU: XLA reference
    ("tpu", "pallas", (True, False)),
    ("gpu", "pallas", (True, True)),    # explicit override only
    ("cpu", "pallas", (True, True)),
    ("tpu", "ref", (False, False)),
    ("cpu", "ref", (False, False)),
])
def test_dispatch_per_backend(monkeypatch, backend, impl, want):
    monkeypatch.setattr(jax, "default_backend", lambda: backend)
    assert ops.dispatch(impl) == want


def test_dispatch_rejects_unknown_impl():
    with pytest.raises(ValueError):
        ops.dispatch("mosaic")
    with pytest.raises(ValueError):
        ops.dispatch("")


def test_auto_never_traces_pallas_off_tpu(monkeypatch):
    """On a simulated GPU backend, the auto wrappers must produce the
    reference results without touching the Pallas kernels at all — if the
    kernel were traced (even in interpret mode) the sentinel would fire."""
    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")

    def boom(*a, **k):
        raise AssertionError("auto dispatched Pallas off-TPU")

    monkeypatch.setattr(ops, "_flash", boom)
    monkeypatch.setattr(ops, "_auc_kernel", boom)
    monkeypatch.setattr(ops, "_prox_kernel", boom)

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 8, 2, 4))
    k = jax.random.normal(key, (1, 8, 1, 4))
    o = ops.attention(q, k, k, causal=True, impl="auto")
    np.testing.assert_allclose(
        np.asarray(o), np.asarray(ref.attention_full(q, k, k, causal=True)),
        atol=1e-6)

    h = jax.random.uniform(key, (64,))
    y = (jax.random.uniform(key, (64,)) < 0.7).astype(jnp.float32)
    got = ops.auc_loss(h, y, 0.1, 0.2, 0.0, 0.7, impl="auto")
    want = ref.auc_loss_ref(h, y, 0.1, 0.2, 0.0, 0.7)
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-6)

    v = jax.random.normal(key, (32,))
    got = ops.prox_update_tree({"w": v}, {"w": v}, {"w": v}, 0.1, 0.5,
                               impl="auto")
    np.testing.assert_allclose(
        np.asarray(got["w"]),
        np.asarray(ref.prox_update_ref(v, v, v, 0.1, 0.5)), atol=1e-6)


def test_auto_never_traces_grouped_matmul_off_tpu(monkeypatch):
    """Same invariant for the sorted-dispatch grouped GEMM: "auto" on a
    non-TPU backend must reach the blocked-scan jnp reference, never the
    (interpret-mode) Pallas kernel."""
    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")

    def boom(*a, **k):
        raise AssertionError("auto dispatched the grouped-GEMM Pallas "
                             "kernel off-TPU")

    monkeypatch.setattr(ops, "_grouped_kernel", boom)
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (6, 4))
    w = jax.random.normal(key, (3, 4, 8))
    gs = jnp.asarray([2, 3, 1], jnp.int32)
    got = ops.grouped_matmul(x, w, gs, impl="auto")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.grouped_matmul_ref(x, w, gs)),
        atol=1e-6)


def test_explicit_pallas_grouped_matmul_interprets_off_tpu():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (6, 4))
    w = jax.random.normal(key, (3, 4, 8))
    gs = jnp.asarray([2, 3, 1], jnp.int32)
    got = ops.grouped_matmul(x, w, gs, impl="pallas")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.grouped_matmul_ref(x, w, gs)),
        atol=1e-5)


def test_explicit_pallas_interprets_off_tpu():
    """impl="pallas" off-TPU is the deliberate interpret-mode escape hatch
    and must still agree with the reference."""
    key = jax.random.PRNGKey(1)
    v = jax.random.normal(key, (64,))
    got = ops.prox_update_tree({"w": v}, {"w": v}, {"w": v}, 0.1, 0.5,
                               impl="pallas")
    np.testing.assert_allclose(
        np.asarray(got["w"]),
        np.asarray(ref.prox_update_ref(v, v, v, 0.1, 0.5)), atol=1e-5)
