"""CoDA algorithm tests: structural equivalences (K=1 ⇒ PPD-SG, I=1 ⇒
NP-PPD-SG), the paper's boundedness lemmas as hypothesis properties, and
end-to-end convergence (AUC > 0.9 on separable synthetic data)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import mlp_config
from repro.core import baselines, coda, objective, schedules
from repro.data import DataConfig, ShardedDataset

MCFG = mlp_config(n_features=16, d=32)


def _ccfg(K, p=0.7):
    return coda.CoDAConfig(n_workers=K, p_pos=p)


def _window(key, I, K, B, p=0.7):
    kx, ky = jax.random.split(key)
    y = (jax.random.uniform(ky, (I, K, B)) < p).astype(jnp.float32)
    x = jax.random.normal(kx, (I, K, B, 16)) + 0.3 * (y[..., None] * 2 - 1)
    return {"features": x, "labels": y}


def _spread(state):
    leaves = jax.tree_util.tree_leaves(state["params"])
    return max(float(jnp.max(jnp.abs(l - l[0:1]))) for l in leaves)


def test_average_syncs_workers():
    key = jax.random.PRNGKey(0)
    st_ = coda.init_state(key, MCFG, _ccfg(4))
    wb = _window(key, 3, 4, 8)
    st2, _ = coda.window_step(MCFG, _ccfg(4), st_, wb, 0.1, communicate=False)
    assert _spread(st2) > 1e-6  # local steps diverge across workers
    st3 = coda.average(st2)
    assert _spread(st3) < 1e-7
    # averaging preserves the mean
    m2 = jnp.mean(st2["params"]["score_head"]["w"], axis=0)
    m3 = jnp.mean(st3["params"]["score_head"]["w"], axis=0)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(m3), atol=1e-7)


def test_window_equals_manual_steps():
    """window_step(I) must equal I explicit local_steps + one average."""
    key = jax.random.PRNGKey(1)
    ccfg = _ccfg(2)
    st0 = coda.init_state(key, MCFG, ccfg)
    wb = _window(key, 4, 2, 8)
    out1, _ = coda.window_step(MCFG, ccfg, st0, wb, 0.05)
    st_m = st0
    for i in range(4):
        st_m, _ = coda.local_step(MCFG, ccfg, st_m,
                                  jax.tree_util.tree_map(lambda a: a[i], wb), 0.05)
    st_m = coda.average(st_m)
    for l1, l2 in zip(jax.tree_util.tree_leaves(out1),
                      jax.tree_util.tree_leaves(st_m)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)


def test_k1_is_ppd_sg():
    """With K=1, averaging is a no-op: CoDA reduces to PPD-SG exactly."""
    key = jax.random.PRNGKey(2)
    ccfg = _ccfg(1)
    st0 = coda.init_state(key, MCFG, ccfg)
    wb = _window(key, 3, 1, 8)
    with_avg, _ = coda.window_step(MCFG, ccfg, st0, wb, 0.05, communicate=True)
    without, _ = coda.window_step(MCFG, ccfg, st0, wb, 0.05, communicate=False)
    for l1, l2 in zip(jax.tree_util.tree_leaves(with_avg),
                      jax.tree_util.tree_leaves(without)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-7)


def test_i1_is_np_ppd_sg():
    """I=1 must match the NP-PPD-SG baseline helper step-for-step."""
    key = jax.random.PRNGKey(3)
    ccfg = _ccfg(4)
    st0 = coda.init_state(key, MCFG, ccfg)
    wb = _window(key, 3, 4, 8)
    # I=1 three times
    s1 = st0
    for i in range(3):
        s1, _ = coda.window_step(
            MCFG, ccfg, s1, jax.tree_util.tree_map(lambda a: a[i:i + 1], wb), 0.05)
    s2, _ = baselines.np_ppd_sg_window(MCFG, ccfg, st0, wb, 0.05)
    for l1, l2 in zip(jax.tree_util.tree_leaves(s1),
                      jax.tree_util.tree_leaves(s2)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(p=st.floats(0.2, 0.8), eta=st.floats(0.01, 0.4),
       seed=st.integers(0, 1000))
def test_lemma7_8_bounds(p, eta, seed):
    """Lemma 7: |α_t| ≤ max(p,1-p)/(p(1-p)); Lemma 8: |a_t|,|b_t| ≤ 1 —
    under the update rules, given h ∈ [0,1] and step-size conditions."""
    bound_alpha = max(p, 1 - p) / (p * (1 - p))
    eta = min(eta, 1 / (2 * p * (1 - p)), 1 / (2 * p), 1 / (2 * (1 - p)))
    key = jax.random.PRNGKey(seed)
    gamma = 0.5
    a = b = alpha = 0.0
    ref_a = ref_b = 0.0
    for _t in range(30):
        key, kh, ky = jax.random.split(key, 3)
        h = jax.random.uniform(kh, (32,))
        y = (jax.random.uniform(ky, (32,)) < p).astype(jnp.float32)
        from repro.kernels.ref import auc_loss_ref
        _, _, da, db, dal = auc_loss_ref(h, y, a, b, alpha, p)
        da, db, dal = float(da), float(db), float(dal)
        a = (gamma * (a - eta * da) + eta * ref_a) / (eta + gamma)
        b = (gamma * (b - eta * db) + eta * ref_b) / (eta + gamma)
        alpha = alpha + eta * dal
        assert abs(a) <= 1 + 1e-5
        assert abs(b) <= 1 + 1e-5
        assert abs(alpha) <= bound_alpha + 1e-4


def test_stage_end_sets_alpha_and_reference():
    key = jax.random.PRNGKey(4)
    ccfg = _ccfg(4)
    st0 = coda.init_state(key, MCFG, ccfg)
    wb = _window(key, 2, 4, 16)
    st1, _ = coda.window_step(MCFG, ccfg, st0, wb, 0.1)
    ab = jax.tree_util.tree_map(lambda a: a[0], wb)
    st2 = coda.stage_end(MCFG, ccfg, st1, ab)
    # alpha identical on all workers, reference moved to current params
    alpha = st2["duals"]["alpha"]
    assert float(jnp.max(jnp.abs(alpha - alpha[0]))) == 0.0
    # the proximal dual references moved to the pre-stage duals
    for f in ("a", "b"):
        np.testing.assert_array_equal(np.asarray(st2["ref_duals"][f]),
                                      np.asarray(st1["duals"][f]))
    for l1, l2 in zip(jax.tree_util.tree_leaves(st2["ref_params"]),
                      jax.tree_util.tree_leaves(st2["params"])):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2))


@pytest.mark.parametrize("K,I", [(1, 1), (4, 8)])
def test_convergence_auc(K, I):
    """End-to-end: CoDA reaches AUC > 0.9 on separable imbalanced data, for
    both the PPD-SG special case and a communication-skipping setting."""
    key = jax.random.PRNGKey(5)
    dcfg = DataConfig(kind="features", n_features=16, signal=2.0)
    ds = ShardedDataset(key, dcfg, 4096, K, target_p=0.71)
    ccfg = coda.CoDAConfig(n_workers=K, p_pos=ds.p_pos)
    sched = schedules.ScheduleConfig(n_workers=K, eta0=0.5, T0=48, I0=I)
    res = coda.fit(key, MCFG, ccfg, sched, 2,
                   sample_window=lambda k, i: ds.sample_window(k, i, 32),
                   sample_alpha_batch=lambda k, m: ds.sample_alpha_batch(k, m))
    test = ds.full(1024)
    from repro.models import model as M
    params0 = jax.tree_util.tree_map(lambda x: x[0], res.state["params"])
    h, _ = M.score(MCFG, params0, {"features": test["features"]})
    auc = float(objective.roc_auc(h, test["labels"]))
    assert auc > 0.9, auc
    assert res.comm_rounds == sum(-(-s.T // s.I) + 1
                                  for s in schedules.stages(sched, 2))


def test_loss_decreases():
    key = jax.random.PRNGKey(6)
    ccfg = _ccfg(4)
    st_ = coda.init_state(key, MCFG, ccfg)
    losses = []
    for _t in range(25):
        key, sk = jax.random.split(key)
        st_, ls = coda.window_step(MCFG, ccfg, st_, _window(sk, 2, 4, 32), 0.2)
        losses.append(float(jnp.mean(ls)))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
