"""Substrate tests: data pipeline, checkpointing, schedules, objective
metrics, serving engine."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro import checkpoint
from repro.configs import get_smoke_config
from repro.core import objective, schedules
from repro.data import DataConfig, ShardedDataset, sample_online
from repro.models import init_params
from repro.serving import Request, ServingEngine


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------
def test_imbalance_targets_positive_ratio():
    key = jax.random.PRNGKey(0)
    ds = ShardedDataset(key, DataConfig(kind="features"), 20000, 4,
                        target_p=0.71)
    assert abs(ds.p_pos - 0.71) < 0.03


def test_shards_are_disjoint_and_balanced():
    key = jax.random.PRNGKey(1)
    ds = ShardedDataset(key, DataConfig(kind="features"), 4096, 8)
    all_idx = np.concatenate(ds.shards)
    assert len(set(all_idx.tolist())) == len(all_idx)  # disjoint
    sizes = {len(s) for s in ds.shards}
    assert len(sizes) == 1  # evenly divided


def test_window_shapes_and_worker_isolation():
    key = jax.random.PRNGKey(2)
    ds = ShardedDataset(key, DataConfig(kind="tokens", vocab_size=64,
                                        seq_len=12), 1024, 4)
    wb = ds.sample_window(key, 3, 8)
    assert wb["tokens"].shape == (3, 4, 8, 12)
    assert wb["labels"].shape == (3, 4, 8)


def test_online_sampling_ratio():
    key = jax.random.PRNGKey(3)
    b = sample_online(key, DataConfig(kind="features", p_pos=0.71), (4096,))
    assert abs(float(b["labels"].mean()) - 0.71) < 0.03


def test_planted_signal_is_learnable_marker():
    """Positive token sequences must contain more motif tokens."""
    key = jax.random.PRNGKey(4)
    dcfg = DataConfig(kind="tokens", vocab_size=100, seq_len=50, signal=1.0)
    b = sample_online(key, dcfg, (2048,))
    motif = b["tokens"] < 10
    rate_pos = float(motif[b["labels"] > 0.5].mean())
    rate_neg = float(motif[b["labels"] < 0.5].mean())
    assert rate_pos > rate_neg + 0.1


# --------------------------------------------------------------------------
# checkpoint
# --------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("qwen2.5-14b")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    p = checkpoint.save(str(tmp_path), 7, params, {"note": "x"})
    assert os.path.isdir(p)
    assert checkpoint.latest_step(str(tmp_path)) == 7
    restored = checkpoint.restore(str(tmp_path), 7, params)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_structure_mismatch_raises(tmp_path):
    checkpoint.save(str(tmp_path), 1, {"a": jnp.ones(3)})
    with pytest.raises(ValueError):
        checkpoint.restore(str(tmp_path), 1, {"b": jnp.ones(3)})


# --------------------------------------------------------------------------
# schedules (Theorem 1)
# --------------------------------------------------------------------------
def test_theorem1_schedule_shapes():
    sc = schedules.ScheduleConfig(n_workers=16, eta0=0.01, T0=100,
                                  mode="theorem1", mu_over_L=0.05, p_pos=0.7)
    sts = schedules.stages(sc, 6)
    etas = [s.eta for s in sts]
    assert all(e1 > e2 for e1, e2 in zip(etas, etas[1:]))      # η decays
    assert all(s1.T <= s2.T for s1, s2 in zip(sts, sts[1:]))    # T grows
    assert all(s1.I <= s2.I for s1, s2 in zip(sts, sts[1:]))    # I grows
    for s in sts:  # I_s = max(1, 1/sqrt(K η_s))
        assert s.I == max(1, int(round(1 / math.sqrt(16 * s.eta))))


@settings(max_examples=20, deadline=None)
@given(K=st.integers(1, 64), eta0=st.floats(1e-3, 1.0))
def test_more_workers_communicate_more_often(K, eta0):
    """Theorem 1 remark (i): larger K ⇒ smaller I (more communication)."""
    s1 = schedules.stage(schedules.ScheduleConfig(n_workers=K, eta0=eta0,
                                                  mode="theorem1"), 1)
    s2 = schedules.stage(schedules.ScheduleConfig(n_workers=4 * K, eta0=eta0,
                                                  mode="theorem1"), 1)
    assert s2.I <= s1.I


def test_practical_matches_paper_experiments():
    sc = schedules.ScheduleConfig(n_workers=16, eta0=0.1, T0=2000, I0=64)
    sts = schedules.stages(sc, 3)
    assert [s.T for s in sts] == [2000, 6000, 18000]
    assert [s.eta for s in sts] == pytest.approx([0.1, 0.1 / 3, 0.1 / 9])
    assert all(s.I == 64 for s in sts)
    grow = schedules.ScheduleConfig(n_workers=16, eta0=0.1, T0=200, I0=4,
                                    grow_I=True)
    assert [s.I for s in schedules.stages(grow, 3)] == [4, 12, 36]


# --------------------------------------------------------------------------
# objective metrics
# --------------------------------------------------------------------------
def test_roc_auc_against_pairwise_count():
    key = jax.random.PRNGKey(5)
    s = jax.random.uniform(key, (200,))
    y = (jax.random.uniform(jax.random.PRNGKey(6), (200,)) < 0.4).astype(jnp.float32)
    auc = float(objective.roc_auc(s, y))
    sp = np.asarray(s)[np.asarray(y) > 0.5]
    sn = np.asarray(s)[np.asarray(y) < 0.5]
    naive = np.mean((sp[:, None] > sn[None, :]) + 0.5 * (sp[:, None] == sn[None, :]))
    assert abs(auc - naive) < 1e-5


def test_roc_auc_with_ties():
    s = jnp.array([0.5, 0.5, 0.5, 0.5])
    y = jnp.array([1.0, 0.0, 1.0, 0.0])
    assert abs(float(objective.roc_auc(s, y)) - 0.5) < 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_optimal_alpha_maximizes_batch_F(seed):
    """α* from Alg.1 lines 4–7 maximizes the batch F when p matches the
    batch composition."""
    key = jax.random.PRNGKey(seed)
    h = jax.random.uniform(key, (64,))
    y = (jax.random.uniform(jax.random.PRNGKey(seed + 1), (64,)) < 0.5).astype(jnp.float32)
    npos = float(y.sum())
    if npos in (0.0, 64.0):
        return
    p = npos / 64
    from repro.kernels.ref import auc_loss_ref
    a_star = float(objective.optimal_alpha(h, y))
    f_star = float(auc_loss_ref(h, y, 0.2, 0.3, a_star, p)[0])
    for d in (-0.1, 0.1, 0.5):
        assert f_star >= float(auc_loss_ref(h, y, 0.2, 0.3, a_star + d, p)[0]) - 1e-6


# --------------------------------------------------------------------------
# serving engine
# --------------------------------------------------------------------------
def test_engine_serves_batched_requests():
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, slots=2, max_len=48)
    reqs = [Request(uid=i, prompt=[3 + i, 7, 11], max_new_tokens=4)
            for i in range(5)]
    for r in reqs:
        eng.add_request(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 4 for r in reqs)


def test_engine_matches_single_request_decode():
    """Batched/continuous decoding must not change a request's tokens."""
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = [5, 9, 2, 14]

    def run(slots, extra):
        eng = ServingEngine(cfg, params, slots=slots, max_len=48)
        req = Request(uid=0, prompt=prompt, max_new_tokens=5)
        eng.add_request(req)
        for i, e in enumerate(extra):
            eng.add_request(Request(uid=1 + i, prompt=e, max_new_tokens=5))
        eng.run()
        return req.generated

    alone = run(1, [])
    batched = run(2, [[8, 1], [4, 4, 4]])
    assert alone == batched
