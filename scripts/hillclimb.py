"""§Perf hillclimbing driver: lowers optimization VARIANTS of the three
chosen (arch × shape) pairs and records before/after roofline terms.

Each experiment is a (tag, overrides) pair fed to
``repro.launch.dryrun.run_pair``; results land in
``benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>__<tag>.json`` and are
summarized into §Perf by hand (the hypothesis log lives in EXPERIMENTS.md).

  PYTHONPATH=src python scripts/hillclimb.py --exp <name>

Serving-path variants (``--serve-exp``) hillclimb the continuous-batching
engine's knobs instead: each experiment is an (engine_kw, trace_kw) override
pair run through ``serving.loadgen.serve_load_report``; the latency/
throughput record lands in ``benchmarks/artifacts/serve/<name>.json``.

  PYTHONPATH=src python scripts/hillclimb.py --serve-exp <name>

Optimizer-seam variants (``--opt-exp``) hillclimb the local-optimizer
knobs (core/optimizer.py: η per optimizer, shampoo block size, stale
preconditioner cadence, bf16 accumulators) on the convergence setting the
``optimizer_window`` bench tier measures; records land in
``benchmarks/artifacts/opt/<name>.json``.

  PYTHONPATH=src python scripts/hillclimb.py --opt-exp <name>
"""
import argparse
import json
import os
import sys

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(HERE, "src"))

import jax.numpy as jnp  # noqa: E402


def experiments():
    """name -> (arch, shape, multi_pod, overrides)"""
    return {
        # ---- pair 1: qwen2.5-14b × train_4k (K=16 replica — the paper's
        # own setting; avg all-reduce is the collective) -------------------
        "qwen_train_int8avg": ("qwen2.5-14b", "train_4k", False,
                               {"avg_compress": "int8", "tag": "int8avg"}),
        "arctic_int8avg": ("arctic-480b", "train_4k", True,
                           {"avg_compress": "int8", "tag": "int8avg"}),
        # ---- pair 2: dbrx-132b × train_4k (worst useful-FLOPs ratio:
        # MoE dispatch replication) ---------------------------------------
        "dbrx_cap1": ("dbrx-132b", "train_4k", False,
                      {"mcfg_kw": {}, "tag": "cap1const",
                       "moe_constraints": True, "moe_capacity": 1.0}),
        "dbrx_constraints": ("dbrx-132b", "train_4k", False,
                             {"moe_constraints": True, "tag": "constraints"}),
        "arctic_prefill_constraints": ("arctic-480b", "prefill_32k", False,
                                       {"moe_constraints": True,
                                        "tag": "constraints"}),
        # ---- pair 3: decode_32k collective-bound: head_dim-sharded cache
        # forces per-layer score all-reduces; flash-decode seq sharding and
        # the int8 cache attack collective and memory terms respectively ----
        "qwen_decode_seq": ("qwen2.5-14b", "decode_32k", False,
                            {"cache_shard": "seq", "tag": "seqshard"}),
        "qwen_decode_seq_int8": ("qwen2.5-14b", "decode_32k", False,
                                 {"cache_shard": "seq",
                                  "cache_dtype": jnp.int8,
                                  "tag": "seqint8"}),
        "qwen_decode_int8": ("qwen2.5-14b", "decode_32k", False,
                             {"cache_dtype": jnp.int8, "tag": "int8cache"}),
        "stablelm_decode_int8": ("stablelm-1.6b", "decode_32k", False,
                                 {"cache_dtype": jnp.int8, "tag": "int8cache"}),
        # internvl2 train was heavily collective-bound: vocab 92553 is not
        # divisible by 16 so the embedding/lm head replicate; pad to 92560
        "internvl2_padvocab": ("internvl2-2b", "train_4k", False,
                               {"mcfg_kw": {"vocab_size": 92560},
                                "tag": "padvocab"}),
    }


def serve_experiments():
    """name -> (engine_kw overrides, trace_kw overrides) for the serving
    engine's batching knobs (slots, prefill chunk, admission policy, prefix
    cache) under a shared poisson trace."""
    trace = {"kind": "poisson", "rate": 48.0, "n_requests": 24,
             "prompt_len": (16, 49), "max_new": (2, 6), "seed": 1}
    return {
        "serve_base": ({}, dict(trace)),
        "serve_slots2": ({"slots": 2}, dict(trace)),
        "serve_slots8": ({"slots": 8}, dict(trace)),
        "serve_chunk1": ({"prefill_chunk": 1}, dict(trace)),
        "serve_chunk16": ({"prefill_chunk": 16}, dict(trace)),
        "serve_sjf": ({"admission": "sjf"}, dict(trace)),
        "serve_prefix": ({"prefix_cache_size": 8},
                         dict(trace, prefix_pool=2, prefix_len=16)),
        "serve_bursty": ({}, dict(trace, kind="bursty", burst_size=8,
                                  rate=32.0)),
    }


def opt_experiments():
    """name -> _run(...) override kwargs for the optimizer-seam knobs
    (core/optimizer.py), hillclimbed on the α=0.1 Dirichlet convergence
    setting ``benchmarks/run.py --only optimizer_window`` measures.  Each
    run records final AUC / comm rounds / per-worker optimizer-state bytes
    so an η, block-size, or refresh-cadence claim in EXPERIMENTS.md has an
    artifact behind it."""
    base = dict(K=8, I=8, dirichlet_alpha=0.1, stages=2, T0=24, batch=16,
                n_data=2048)
    return {
        "opt_sgd_base": dict(base, optimizer="sgd", eta0=0.5),
        "opt_sm3": dict(base, optimizer="sm3", eta0=0.3),
        "opt_sm3_bf16": dict(base, optimizer="sm3", eta0=0.3,
                             opt_dtype="bfloat16"),
        "opt_sm3_eta_hi": dict(base, optimizer="sm3", eta0=0.6),
        "opt_shampoo": dict(base, optimizer="shampoo_blocked", eta0=0.5,
                            shampoo_block=16, precond_every=2),
        "opt_shampoo_bf16": dict(base, optimizer="shampoo_blocked", eta0=0.5,
                                 shampoo_block=16, precond_every=2,
                                 opt_dtype="bfloat16"),
        "opt_shampoo_b8": dict(base, optimizer="shampoo_blocked", eta0=0.5,
                               shampoo_block=8, precond_every=2),
        "opt_shampoo_b32": dict(base, optimizer="shampoo_blocked", eta0=0.5,
                                shampoo_block=32, precond_every=2),
        "opt_shampoo_stale4": dict(base, optimizer="shampoo_blocked",
                                   eta0=0.5, shampoo_block=16,
                                   precond_every=4),
        "opt_momentum": dict(base, optimizer="momentum", eta0=0.3,
                             opt_beta=0.9),
    }


def run_opt(name: str) -> None:
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "bench_run", os.path.join(HERE, "benchmarks", "run.py"))
    bench = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(bench)
    kw = dict(opt_experiments()[name])
    K, I = kw.pop("K"), kw.pop("I")
    if kw.get("opt_dtype") == "bfloat16":
        kw["opt_dtype"] = jnp.bfloat16
    r = bench._run(K, I, **kw)
    rec = {"name": name, "K": K, "I": I,
           **{k: (v if not hasattr(v, "dtype") else str(v)) for k, v in
              opt_experiments()[name].items() if k not in ("K", "I")},
           "auc": r["auc"], "rounds": r["rounds"],
           "opt_state_bytes": r["opt_state_bytes"],
           "payload_bytes": r["payload_bytes"],
           "us_per_iter": r["us_per_iter"]}
    out_dir = os.path.join(HERE, "benchmarks", "artifacts", "opt")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, default=str)
    print(f"{name}: auc={r['auc']:.4f} rounds={r['rounds']} "
          f"opt_state={r['opt_state_bytes']:,}B -> {path}")


def run_serve(name: str) -> None:
    from repro.serving.loadgen import serve_load_report
    engine_kw, trace_kw = serve_experiments()[name]
    rec = serve_load_report(engine_kw=engine_kw, trace_kw=trace_kw)
    out_dir = os.path.join(HERE, "benchmarks", "artifacts", "serve")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, default=str)
    m = rec["metrics"]
    print(f"{name}: tokens/s={m['tokens_per_s']:.1f} "
          f"ttft_p50={m['ttft_p50_ms']:.1f}ms "
          f"latency_p99={m['latency_p99_ms']:.1f}ms -> {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", choices=list(experiments()))
    ap.add_argument("--serve-exp", choices=list(serve_experiments()))
    ap.add_argument("--opt-exp", choices=list(opt_experiments()))
    args = ap.parse_args()
    if sum(map(bool, (args.exp, args.serve_exp, args.opt_exp))) != 1:
        ap.error("pass exactly one of --exp / --serve-exp / --opt-exp")
    if args.serve_exp:
        run_serve(args.serve_exp)
        return
    if args.opt_exp:
        run_opt(args.opt_exp)
        return
    from repro.launch.dryrun import run_pair
    arch, shape, mp, ov = experiments()[args.exp]
    if "moe_capacity" in ov:
        # capacity factor is threaded through the MoE config
        from repro.configs import get_config
        m = get_config(arch).moe
        import dataclasses
        ov = dict(ov)
        ov["mcfg_kw"] = {"moe": dataclasses.replace(
            m, capacity_factor=ov.pop("moe_capacity"))}
    tag = "__" + ov.get("tag", args.exp)
    run_pair(arch, shape, multi_pod=mp, overrides=ov, tag_suffix=tag)


if __name__ == "__main__":
    main()
