"""§Perf hillclimbing driver: lowers optimization VARIANTS of the three
chosen (arch × shape) pairs and records before/after roofline terms.

Each experiment is a (tag, overrides) pair fed to
``repro.launch.dryrun.run_pair``; results land in
``benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>__<tag>.json`` and are
summarized into §Perf by hand (the hypothesis log lives in EXPERIMENTS.md).

  PYTHONPATH=src python scripts/hillclimb.py --exp <name>
"""
import argparse
import os
import sys

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(HERE, "src"))

import jax.numpy as jnp  # noqa: E402


def experiments():
    """name -> (arch, shape, multi_pod, overrides)"""
    return {
        # ---- pair 1: qwen2.5-14b × train_4k (K=16 replica — the paper's
        # own setting; avg all-reduce is the collective) -------------------
        "qwen_train_int8avg": ("qwen2.5-14b", "train_4k", False,
                               {"avg_compress": "int8", "tag": "int8avg"}),
        "arctic_int8avg": ("arctic-480b", "train_4k", True,
                           {"avg_compress": "int8", "tag": "int8avg"}),
        # ---- pair 2: dbrx-132b × train_4k (worst useful-FLOPs ratio:
        # MoE dispatch replication) ---------------------------------------
        "dbrx_cap1": ("dbrx-132b", "train_4k", False,
                      {"mcfg_kw": {}, "tag": "cap1const",
                       "moe_constraints": True, "moe_capacity": 1.0}),
        "dbrx_constraints": ("dbrx-132b", "train_4k", False,
                             {"moe_constraints": True, "tag": "constraints"}),
        "arctic_prefill_constraints": ("arctic-480b", "prefill_32k", False,
                                       {"moe_constraints": True,
                                        "tag": "constraints"}),
        # ---- pair 3: decode_32k collective-bound: head_dim-sharded cache
        # forces per-layer score all-reduces; flash-decode seq sharding and
        # the int8 cache attack collective and memory terms respectively ----
        "qwen_decode_seq": ("qwen2.5-14b", "decode_32k", False,
                            {"cache_shard": "seq", "tag": "seqshard"}),
        "qwen_decode_seq_int8": ("qwen2.5-14b", "decode_32k", False,
                                 {"cache_shard": "seq",
                                  "cache_dtype": jnp.int8,
                                  "tag": "seqint8"}),
        "qwen_decode_int8": ("qwen2.5-14b", "decode_32k", False,
                             {"cache_dtype": jnp.int8, "tag": "int8cache"}),
        "stablelm_decode_int8": ("stablelm-1.6b", "decode_32k", False,
                                 {"cache_dtype": jnp.int8, "tag": "int8cache"}),
        # internvl2 train was heavily collective-bound: vocab 92553 is not
        # divisible by 16 so the embedding/lm head replicate; pad to 92560
        "internvl2_padvocab": ("internvl2-2b", "train_4k", False,
                               {"mcfg_kw": {"vocab_size": 92560},
                                "tag": "padvocab"}),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", required=True, choices=list(experiments()))
    args = ap.parse_args()
    from repro.launch.dryrun import run_pair
    arch, shape, mp, ov = experiments()[args.exp]
    if "moe_capacity" in ov:
        # capacity factor is threaded through the MoE config
        from repro.configs import get_config
        m = get_config(arch).moe
        import dataclasses
        ov = dict(ov)
        ov["mcfg_kw"] = {"moe": dataclasses.replace(
            m, capacity_factor=ov.pop("moe_capacity"))}
    tag = "__" + ov.get("tag", args.exp)
    run_pair(arch, shape, multi_pod=mp, overrides=ov, tag_suffix=tag)


if __name__ == "__main__":
    main()
