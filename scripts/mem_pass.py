"""Memory pass: re-lower each pod1 pair with ROLLED scans (the production
configuration — unrolling distorts XLA's live-range analysis) and update the
artifact's ``memory_rolled`` field with that module's memory_analysis().

For MoE archs every EVAL artifact (prefill/decode shapes — training always
uses capacity dispatch, so there is no before/after there) additionally
gets a ``moe_dispatch_bytes`` record: the per-layer dispatch-buffer bytes
the pass's token count implies under the padded capacity dispatch (before:
[E, C=T, d]) vs the sorted dropless dispatch (after: [T·k, d]) — see
models/moe.py and ``benchmarks/run.py --only moe_dispatch``.

Every TRAIN artifact additionally gets an ``optimizer_state_bytes``
record: per-worker accumulator bytes for each stateful registry optimizer
(core/optimizer.py) in fp32 vs bf16 storage, computed analytically via
``jax.eval_shape`` — the local-memory side of the optimizer seam (the
wire side is pinned by the audit's window-payload rule).

  PYTHONPATH=src python scripts/mem_pass.py [--arch X --shape Y]
"""
import argparse
import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(HERE, "benchmarks", "artifacts", "dryrun")
sys.path.insert(0, os.path.join(HERE, "src"))

RUNNER = """
import os, json, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro import flags
from repro.launch import dryrun as DR
flags.DRYRUN_UNROLL = False  # rolled: the production module
arch, shape = sys.argv[1], sys.argv[2]
from repro.launch import mesh as MESH
mesh = MESH.make_production_mesh(multi_pod=False)
lowered, meta = DR.build_lowering(arch, shape, mesh, variant="full")
compiled = lowered.compile()
mem = compiled.memory_analysis()
from repro.analysis import hlo as H
coll = H.collective_bytes(compiled.as_text())
ca = compiled.cost_analysis() or {}
rec = {
    "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
    "output_bytes": getattr(mem, "output_size_in_bytes", None),
    "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
    "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
    "rolled_coll_bytes": coll["total_bytes"],
    "rolled_flops": float(ca.get("flops", 0.0)),
}
print("MEMJSON " + json.dumps(rec))
"""


def moe_dispatch_record(arch: str, shape_name: str):
    """Analytic before/after dispatch-buffer bytes for one (arch, shape).
    Returns None for non-MoE archs and for train shapes (training always
    uses capacity dispatch — the sorted path is eval/decode-only, so a
    before/after there would be fiction)."""
    from repro.configs import SHAPES, get_config
    from repro.models import moe
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    if cfg.moe is None or spec.kind == "train":
        return None
    T = moe.tokens_per_forward(spec)
    cap = moe.dispatch_buffer_bytes(cfg, T, mode="capacity", dtype="bfloat16")
    srt = moe.dispatch_buffer_bytes(cfg, T, mode="sorted", dtype="bfloat16")
    return {"tokens": T,
            "capacity_bytes": cap,       # before: [E, C=T, d] per layer
            "sorted_bytes": srt,         # after:  [T·k, d] per layer
            "ratio": cap / srt}


def optimizer_state_record(arch: str, shape_name: str):
    """Analytic per-worker optimizer-state bytes for one (arch, shape):
    every stateful registry optimizer × {fp32, bf16} accumulator storage,
    from ``jax.eval_shape``-traced state (no buffers materialized).  The
    state is strictly LOCAL — it never joins the window payload — so these
    bytes are pure per-worker HBM, and the fp32/bf16 ratio is the memory
    the stochastic-rounded buffers buy back.  None for non-train shapes
    (eval/decode lowering has no optimizer)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import SHAPES, get_config
    from repro.core import coda
    spec = SHAPES[shape_name]
    if spec.kind != "train":
        return None
    mcfg = get_config(arch)
    out = {}
    for optname in ("momentum", "sm3", "shampoo_blocked"):
        per_dt = {}
        for dtn, dt in (("fp32", jnp.float32), ("bf16", jnp.bfloat16)):
            ccfg = coda.CoDAConfig(n_workers=8, optimizer=optname,
                                   opt_dtype=dt)
            sts = jax.eval_shape(
                lambda k, c=ccfg: coda.init_state(k, mcfg, c),
                jax.random.PRNGKey(0))
            per_dt[dtn] = coda.opt_state_bytes(sts)
        per_dt["bf16_reduction"] = round(
            per_dt["fp32"] / max(1, per_dt["bf16"]), 2)
        out[optname] = per_dt
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()
    env = {**os.environ, "PYTHONPATH": os.path.join(HERE, "src")}
    for f in sorted(os.listdir(ART)):
        if not f.endswith("__pod1.json"):
            continue
        rec = json.load(open(os.path.join(ART, f)))
        if rec.get("status") != "ok":
            continue
        arch, shape = rec["arch"], rec["shape"]
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape != args.shape:
            continue
        if "moe_dispatch_bytes" not in rec:
            md = moe_dispatch_record(arch, shape)
            if md is not None:
                rec["moe_dispatch_bytes"] = md
                json.dump(rec, open(os.path.join(ART, f), "w"), indent=1)
                print(f"{f}: moe dispatch buffer {md['ratio']:.0f}x "
                      f"(capacity/sorted)", flush=True)
        if "optimizer_state_bytes" not in rec:
            try:
                od = optimizer_state_record(arch, shape)
            except Exception as e:          # never block the memory pass
                print(f"{f}: optimizer record failed: {e}", flush=True)
                od = None
            if od is not None:
                rec["optimizer_state_bytes"] = od
                json.dump(rec, open(os.path.join(ART, f), "w"), indent=1)
                print(f"{f}: optimizer state/worker " + " ".join(
                    f"{o}={d['bf16']:,}B(bf16,{d['bf16_reduction']}x)"
                    for o, d in od.items()), flush=True)
        if "memory_rolled" in rec:
            continue
        # decode lowerings have no scans — rolled == unrolled already
        if shape in ("decode_32k", "long_500k") and not args.shape:
            continue
        r = subprocess.run([sys.executable, "-c", RUNNER, arch, shape],
                           env=env, cwd=HERE, capture_output=True, text=True,
                           timeout=3000)
        out = [l for l in r.stdout.splitlines() if l.startswith("MEMJSON ")]
        if out:
            rec["memory_rolled"] = json.loads(out[-1][8:])
            json.dump(rec, open(os.path.join(ART, f), "w"), indent=1)
            tb = rec["memory_rolled"].get("temp_bytes")
            print(f"{f}: temp={tb and tb / 2**30:.1f}GiB", flush=True)
        else:
            print(f"{f}: FAILED {r.stderr[-200:]}", flush=True)


if __name__ == "__main__":
    main()
