"""Run the full dry-run sweep: all (arch × shape) on the single-pod mesh
(with L-delta + averaging probes for the roofline), then the multi-pod mesh
(full lowering only — the mesh-coherence proof; the roofline table is
single-pod per the spec).

  PYTHONPATH=src python scripts/sweep_dryrun.py [--skip-existing]
"""
import argparse
import os
import subprocess
import sys
import time

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(HERE, "benchmarks", "artifacts", "dryrun")

ARCHS = ["xlstm-350m", "stablelm-1.6b", "hymba-1.5b", "internvl2-2b",
         "chatglm3-6b", "seamless-m4t-medium", "qwen2.5-14b",
         "phi3-medium-14b", "dbrx-132b", "arctic-480b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

RUNNER = """
import sys
from repro.launch.dryrun import run_pair
run_pair(sys.argv[1], sys.argv[2], multi_pod=(sys.argv[3] == "1"))
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    args = ap.parse_args()

    jobs = []
    for mp in ([True] if args.multi_pod_only else [False, True]):
        for arch in ARCHS:
            for shape in SHAPES:
                jobs.append((arch, shape, mp))

    env = {**os.environ, "PYTHONPATH": os.path.join(HERE, "src")}
    if "REPRO_MULTIPOD_FULL_ONLY" not in env:
        env["REPRO_MULTIPOD_FULL_ONLY"] = "1"
    for i, (arch, shape, mp) in enumerate(jobs):
        tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
        path = os.path.join(ART, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[{i + 1}/{len(jobs)}] {tag}: exists, skip", flush=True)
            continue
        t0 = time.time()
        r = subprocess.run(
            [sys.executable, "-c", RUNNER, arch, shape, "1" if mp else "0"],
            env=env, cwd=HERE, capture_output=True, text=True, timeout=5400)
        out = (r.stdout + r.stderr).strip().splitlines()
        last = out[-1] if out else "?"
        print(f"[{i + 1}/{len(jobs)}] {last}  ({time.time() - t0:.0f}s)",
              flush=True)


if __name__ == "__main__":
    main()
