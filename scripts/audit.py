"""Compiled-program audit CLI — the CI gate over the whole program registry.

Runs the rule engine (src/repro/analysis/audit.py: R1 collective-placement,
R2 donation, R3 host-sync/dtype lint, R4 recompile budget, R5 Pallas static
checks) over every distinct program the repo builds:

  * training: executors × {coda, codasca} × {fp32, int8} × {blocking,
    overlap} (minus the combinations the config layer itself rejects —
    int8 × overlap, sketch × int8)
  * serving: the engine's two chunk programs (C = prefill_chunk, C = 1)
    plus the live compile-count drive
  * kernels: the static launch geometry of every Pallas kernel under each
    dispatch impl

and writes a JSON artifact (one record per leg + the aggregate verdict).
Exit status is the gate: 0 iff every rule passed on every leg.

Usage:
  PYTHONPATH=src python scripts/audit.py --smoke --force-host-devices 8 \
      --json audit.json
  PYTHONPATH=src python scripts/audit.py --only sharded/codasca
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def build_legs(n_devices: int, *, smoke: bool) -> list:
    """The audit matrix as (name, thunk) pairs.  Thunks are lazy so --only
    filters before any compilation happens."""
    from repro.analysis import audit
    from repro.configs.base import mlp_config
    from repro.core.coda import CoDAConfig
    from repro.launch import mesh as M

    if smoke:
        mcfg = mlp_config(n_features=16, d=32)
        window_lens = (1, 2)
    else:
        mcfg = mlp_config(n_features=64, d=128)
        window_lens = (1, 2, 3)
    I = max(window_lens)
    K = n_devices

    def ccfg_for(algorithm: str, compress: str, schedule: str,
                 masked: bool = False) -> CoDAConfig:
        kw = {}
        if masked:      # partial participation: masked window contracts
            kw = dict(participation=0.5, straggler_prob=0.25,
                      max_staleness=1)
        return CoDAConfig(
            n_workers=K, algorithm=algorithm, avg_compress=compress,
            overlap_chunks=2 if schedule == "overlap" else 0, **kw)

    legs = []

    def training_leg(executor: str, algorithm: str, compress: str,
                     schedule: str, masked: bool = False):
        name = f"{executor}/{algorithm}/{compress or 'fp32'}/{schedule}"
        if masked:
            name += "/masked"

        def run():
            ccfg = ccfg_for(algorithm, compress, schedule, masked)
            kw = dict(I=I, B=8, window_lens=window_lens, tag=name)
            if executor == "shard_map":
                kw.update(mesh=M.make_worker_mesh(K), policy="replica")
            programs = audit.capture_training_programs(
                mcfg, ccfg, executor=executor, **kw)
            return audit.run_rules(programs, check_dispatch=False)

        legs.append((name, run))

    # the vmap oracle never overlaps (no wire to hide); the sharded
    # executor runs the full schedule axis, minus int8 × overlap which the
    # config layer rejects by construction
    for algorithm in ("coda", "codasca"):
        for compress in ("", "int8"):
            training_leg("vmap", algorithm, compress, "blocking")
            training_leg("shard_map", algorithm, compress, "blocking")
            if not compress:
                training_leg("shard_map", algorithm, compress, "overlap")

    # partial participation: same R1 contract in masked-payload form — still
    # exactly ONE all-reduce per dtype bucket, payload + the weight lane(s)
    for algorithm in ("coda", "codasca"):
        training_leg("shard_map", algorithm, "", "blocking", masked=True)
        training_leg("shard_map", algorithm, "", "overlap", masked=True)
    training_leg("shard_map", "coda", "int8", "blocking", masked=True)

    # optimizer seam (core/optimizer.py): whatever local preconditioner
    # runs, the window contract is UNCHANGED — the opt state must stay off
    # the wire (capture_sharded_programs pins the payload byte-exactly and
    # passes opt_bytes so a leak is named, not just sized)
    def optimizer_leg(executor: str, optname: str):
        name = f"opt/{optname}/{executor}"

        def run():
            ccfg = CoDAConfig(n_workers=K, optimizer=optname,
                              opt_dtype="bfloat16", shampoo_block=16,
                              precond_every=2)
            kw = dict(I=I, B=8, window_lens=window_lens, tag=name)
            if executor == "shard_map":
                kw.update(mesh=M.make_worker_mesh(K), policy="replica")
            programs = audit.capture_training_programs(
                mcfg, ccfg, executor=executor, **kw)
            return audit.run_rules(programs, check_dispatch=False)

        legs.append((name, run))

    for optname in ("sgd", "sm3", "shampoo_blocked"):
        optimizer_leg("vmap", optname)
        optimizer_leg("shard_map", optname)

    def serving_leg():
        def run():
            programs = audit.capture_serving_programs(
                slots=2, max_len=32, prefill_chunk=4)
            return audit.run_rules(programs, check_dispatch=False)
        legs.append(("serving/chunk_step", run))

    serving_leg()

    def kernel_leg(impl: str):
        def run():
            launches = audit.capture_kernel_launches(impl=impl)
            return audit.run_rules([], launches, rules={"R5"})
        legs.append((f"kernels/{impl}", run))

    for impl in ("auto", "ref", "pallas"):
        kernel_leg(impl)
    return legs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small model + short window axis (the CI matrix)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the audit artifact here")
    ap.add_argument("--only", metavar="SUBSTR",
                    help="run only legs whose name contains SUBSTR")
    ap.add_argument("--list", action="store_true",
                    help="print leg names and exit")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    metavar="N", help="force N XLA host devices (set before "
                    "the first backend touch)")
    args = ap.parse_args(argv)

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    if args.force_host_devices:
        from repro.launch import mesh as M
        M.force_host_device_count(args.force_host_devices)
    import jax

    n_devices = len(jax.devices())
    legs = build_legs(n_devices, smoke=args.smoke)
    if args.only:
        legs = [(n, r) for n, r in legs if args.only in n]
        if not legs:
            print(f"no legs match --only {args.only!r}", file=sys.stderr)
            return 2
    if args.list:
        for name, _ in legs:
            print(name)
        return 0

    records, any_failed = [], False
    for name, run in legs:
        t0 = time.perf_counter()
        try:
            report = run().to_dict()
        except Exception as e:  # a crashed capture is a failed leg, not a
            report = {          # crashed gate — the artifact records it
                "ok": False, "n_checked": 0, "n_findings": 1,
                "rules": {"capture": {"checked": [], "findings": [
                    {"program": name, "message": f"{type(e).__name__}: {e}"},
                ]}}}
        report["leg"] = name
        report["seconds"] = round(time.perf_counter() - t0, 3)
        records.append(report)
        any_failed |= not report["ok"]
        status = "ok" if report["ok"] else "FAIL"
        print(f"[{status}] {name} ({report['n_checked']} checks, "
              f"{report['n_findings']} findings, {report['seconds']}s)")
        for rule, rec in report["rules"].items():
            for f in rec["findings"]:
                print(f"    [{rule}] {f['program']}: {f['message']}")

    artifact = {
        "ok": not any_failed,
        "n_devices": n_devices,
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "legs": records,
    }
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(artifact, fh, indent=2)
        print(f"wrote {args.json}")
    print("audit:", "ok" if artifact["ok"] else "FAILED")
    return 1 if any_failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
