"""Regenerate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run artifacts.  Narrative sections (§Perf, §Paper-validation) live in
EXPERIMENTS.md between markers and are preserved.

  PYTHONPATH=src python scripts/make_experiments.py [--coda-I 8]
"""
import argparse
import glob
import json
import os
import sys

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(HERE, "src"))

from repro.analysis.hlo import V5E  # noqa: E402

ART = os.path.join(HERE, "benchmarks", "artifacts", "dryrun")

ARCH_ORDER = ["chatglm3-6b", "arctic-480b", "dbrx-132b", "internvl2-2b",
              "qwen2.5-14b", "stablelm-1.6b", "seamless-m4t-medium",
              "hymba-1.5b", "phi3-medium-14b", "xlstm-350m"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    recs = {}
    for f in glob.glob(os.path.join(ART, "*.json")):
        rec = json.load(open(f))
        base = os.path.basename(f)[:-5]
        parts = base.split("__")
        if len(parts) < 3 or parts[2] not in ("pod1", "pod2"):
            continue  # hillclimb/override artifacts handled in §Perf by hand
        recs[(parts[0], parts[1], parts[2])] = rec
    return recs


def fmt_bytes(n):
    if n is None:
        return "—"
    for u in ["B", "KB", "MB", "GB", "TB"]:
        if abs(n) < 1024:
            return f"{n:.1f}{u}"
        n /= 1024
    return f"{n:.1f}PB"


def fmt_e(x):
    return f"{x:.2e}" if x is not None else "—"


def roofline(rec, coda_I):
    """Per-device, per-step three terms in seconds.  For CoDA train steps the
    collective term amortizes the averaging all-reduce over I local steps."""
    coll = rec.get("coll_bytes", 0.0)
    note = ""
    if rec.get("step_kind") == "coda_window":
        avg = rec.get("avg_coll_bytes", 0.0)
        internal = max(0.0, coll - avg)
        coll = internal + avg / coda_I
        note = f"I={coda_I}"
    c = rec["flops"] / V5E.peak_flops
    m = rec["hbm_bytes"] / V5E.hbm_bw
    x = coll / V5E.ici_bw
    dom = {"compute": c, "memory": m, "collective": x}
    b = max(dom, key=dom.get)
    return c, m, x, b, note


def model_flops(rec):
    n = rec["n_params_active"]
    d = rec["tokens_per_step"]
    mult = 6.0 if rec["step_kind"] == "coda_window" else 2.0
    return mult * n * d


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | status | step kind | K | policy | "
        "per-dev FLOPs/step | per-dev HBM bytes | coll bytes (HLO) | "
        "peak mem/dev | compile |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for pod in ("pod1", "pod2"):
                rec = recs.get((a, s, pod))
                if rec is None:
                    lines.append(f"| {a} | {s} | {pod} | **missing** | | | | | | | | |")
                    continue
                if rec["status"] == "skipped":
                    lines.append(
                        f"| {a} | {s} | {pod} | skipped | — | — | — | — | — | — "
                        f"| — | ({rec['reason'][:48]}…) |")
                    continue
                if rec["status"] != "ok":
                    lines.append(
                        f"| {a} | {s} | {pod} | **FAILED** | | | | | | | | "
                        f"{rec.get('error', '')[:60]} |")
                    continue
                mem = rec.get("memory_rolled") or rec.get("memory") or {}
                peak = mem.get("temp_bytes")
                lines.append(
                    f"| {a} | {s} | {pod} | ok | {rec['step_kind']} "
                    f"| {rec.get('n_workers', '—')} | {rec['policy']} "
                    f"| {fmt_e(rec['flops'])} | {fmt_e(rec['hbm_bytes'])} "
                    f"| {fmt_e(rec.get('coll_bytes'))} | {fmt_bytes(peak)} "
                    f"| {rec['full_raw']['seconds']}s |")
    return "\n".join(lines)


def roofline_table(recs, coda_I):
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "MODEL_FLOPS/HLO_FLOPs | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            rec = recs.get((a, s, "pod1"))
            if rec is None or rec["status"] != "ok":
                continue
            c, m, x, b, note = roofline(rec, coda_I)
            mf = model_flops(rec)
            # HLO flops are per-device; MODEL_FLOPS is global
            ratio = mf / max(rec["flops"] * rec["n_chips"], 1.0)
            hint = HINTS.get((rec["step_kind"], b), "")
            lines.append(
                f"| {a} | {s}{'(' + note + ')' if note else ''} | {c:.2e} "
                f"| {m:.2e} | {x:.2e} | **{b}** | {ratio:.2f} | {hint} |")
    return "\n".join(lines)


HINTS = {
    ("coda_window", "compute"): "larger I is free here; remat policy / MXU-"
                                "friendlier head dims cut recompute",
    ("coda_window", "collective"): "increase I (CoDA's knob) or "
                                   "reduce-scatter the averaging",
    ("coda_window", "memory"): "fuse prox-update (kernel) + bf16 master copy",
    ("prefill", "compute"): "flash-attention kernel (block-skip) shrinks the "
                            "S² term",
    ("prefill", "memory"): "avoid KV round-trip: fuse cache emission into "
                           "attention",
    ("prefill", "collective"): "shard seq (context parallel) instead of batch",
    ("decode", "memory"): "KV cache is the stream: GQA narrower / quantized "
                          "cache / paged layout",
    ("decode", "compute"): "batch more requests per step",
    ("decode", "collective"): "keep params resident; all-gather per token is "
                              "the bug",
}

MARK_BEGIN = "<!-- AUTOGEN:BEGIN -->"
MARK_END = "<!-- AUTOGEN:END -->"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--coda-I", type=int, default=8)
    args = ap.parse_args()
    recs = load()
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    n_skip = sum(1 for r in recs.values() if r["status"] == "skipped")
    n_fail = len(recs) - n_ok - n_skip

    auto = f"""{MARK_BEGIN}
*(regenerated by `scripts/make_experiments.py` from
`benchmarks/artifacts/dryrun/` — {n_ok} ok / {n_skip} skipped / {n_fail}
failed of {len(recs)} recorded lowerings)*

## §Dry-run

Methodology: AOT `.lower().compile()` on the production meshes with 512
forced host devices; `cost_analysis()` is measured on the partitioned module
(per-device numbers).  XLA counts while-loop bodies once, so cost lowerings
unroll every structural scan (`repro.flags.DRYRUN_UNROLL`); the sequential
sLSTM time scan gets an analytic correction.  Peak memory comes from a
second, ROLLED lowering (the production module — unrolling distorts
live-range analysis); decode paths have no scans so one lowering serves both.
Collective bytes are result-shape sums over `all-reduce | all-gather |
reduce-scatter | all-to-all | collective-permute` in the optimized HLO.
pod1 = (16,16) `(data, model)`; pod2 = (2,16,16) `(pod, data, model)`.
train_4k lowers the CoDA window step at I=1 plus a dedicated averaging-only
lowering, so any interval I is `internal + avg/I` (Theorem 1's trade-off).

{dryrun_table(recs)}

## §Roofline

Single-pod (256 chips), per device per step, v5e-class constants
(197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI).  For CoDA training steps the
collective term is `internal + averaging/I` with I={args.coda_I} (the
averaging all-reduce measured by a dedicated lowering).  MODEL_FLOPS =
6·N_active·D (train) or 2·N_active·D (prefill/decode), global, divided by
global HLO FLOPs (per-device × 256).

{roofline_table(recs, args.coda_I)}
{MARK_END}"""

    path = os.path.join(HERE, "EXPERIMENTS.md")
    if os.path.exists(path):
        text = open(path).read()
        if MARK_BEGIN in text:
            pre = text.split(MARK_BEGIN)[0]
            post = text.split(MARK_END)[1]
            text = pre + auto + post
        else:
            text = text + "\n" + auto
    else:
        text = "# EXPERIMENTS\n\n" + auto + "\n"
    open(path, "w").write(text)
    print(f"wrote {path}: {n_ok} ok, {n_skip} skipped, {n_fail} failed")


if __name__ == "__main__":
    main()
